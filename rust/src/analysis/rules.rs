//! The rule registry: every machine-checked invariant, with the
//! guarantee it protects. See docs/ANALYSIS.md for the prose rationale
//! and the waiver syntax; the constants here are the single source of
//! truth the analyzer, the tests, and the docs check against.

use std::collections::{BTreeMap, BTreeSet};

use crate::tensor::Dtype;
use crate::util::json::Json;

use super::scanner::word_hit;
use super::{
    Finding, Tree, AUX_BASELINE, AUX_CI, AUX_DOCS, AUX_EXCHANGE,
    AUX_MAKEFILE, AUX_README,
};

/// Rule ids + one-line descriptions (the `analyze --list` output and the
/// JSON report's rule table).
pub const RULES: &[(&str, &str)] = &[
    (
        "waiver-syntax",
        "every ANALYZE-WAIVE comment parses as (rule): reason",
    ),
    (
        "no-unsafe",
        "the tree is 100% safe Rust: no `unsafe` tokens, and lib.rs/main.rs \
         carry #![forbid(unsafe_code)]",
    ),
    (
        "determinism",
        "no unordered iteration, stray threads, or unblessed float \
         reductions/clocks in coordinator/, optim/, runtime/",
    ),
    (
        "panic-discipline",
        "unwrap()/expect() in the engine and checkpoint paths stay within \
         the annotated allowlist",
    ),
    (
        "consistency",
        "bench metric names, Makefile targets vs CI steps and README \
         references, the ADCP checkpoint version, and the q8 wire block \
         size stay in sync across artifacts",
    ),
    (
        "hot-path-alloc",
        "no allocation tokens (vec!, to_vec, Vec::with_capacity, .clone, \
         Box::new) inside ANALYZE-HOT regions — the marked steady-state \
         dispatch paths stay heap-free",
    ),
    (
        "lock-order",
        "the global lock-order graph (guard acquisition sets propagated \
         over the call graph) is acyclic — a cycle is a static deadlock \
         witness",
    ),
    (
        "condvar-discipline",
        "every Condvar::wait is reached holding its paired mutex, sits in \
         a predicate loop, and has a matching notify somewhere in the \
         watched tree",
    ),
    (
        "channel-topology",
        "every channel endpoint is used after creation (sends have a live \
         receive path) and every recycled ring buffer recv'd comes back \
         on a ret_* endpoint — the alloc-free steady-state invariant",
    ),
    (
        "lock-held-panic",
        "no unwrap()/expect()/panic-family/indexing-panic token while a \
         MutexGuard is live outside test code — poison on the barrier \
         path wedges the whole crew",
    ),
];

/// Directories (repo-relative prefixes) the determinism and
/// panic-discipline rules police: the paths every bitwise-parity and
/// checkpoint guarantee flows through.
pub const WATCHED_DIRS: &[&str] = &[
    "rust/src/coordinator/",
    "rust/src/optim/",
    "rust/src/runtime/",
];

/// The blessed kernel files: float reductions (`powf`, `exp`,
/// `.sum::<f32>()`) are the kernels' job, in a fixed, tested evaluation
/// order. Everywhere else in the watched tree they are a parity hazard.
pub const BLESSED_FLOAT_FILES: &[&str] =
    &["rust/src/optim/update.rs", "rust/src/optim/flat.rs"];

/// The one file allowed to create threads: `pool.rs` owns the scoped
/// worker pool every parallel path runs on. Threads elsewhere need a
/// waiver explaining why their schedule cannot reorder results.
pub const THREAD_HOME: &str = "rust/src/optim/pool.rs";

/// Identifier tokens whose presence in a watched file is a determinism
/// finding: unordered iteration bleeds into reduce order and eval
/// output.
const UNORDERED_COLLECTIONS: &[&str] = &["HashMap", "HashSet"];

/// Clock reads are nondeterministic inputs; report-only timing is fine
/// but must say so with a waiver.
const CLOCK_TOKENS: &[&str] = &["Instant::now", "SystemTime::now"];

/// Float reductions/transcendentals outside the blessed kernels — the
/// operations whose evaluation order decides bitwise parity.
const FLOAT_TOKENS: &[&str] = &[".powf(", ".exp(", ".sum::<f32>()"];

/// Per-file unwrap()/expect() budgets (non-test code) inside the watched
/// dirs, each with the reason the calls are sound. A file exceeding its
/// budget — or absent here with a nonzero count — fails `analyze`;
/// adding a budget entry IS the explicit waiver path for this rule.
/// Counts below budget are reported as ratchet notes so budgets only
/// ever shrink.
pub const PANIC_ALLOWLIST: &[(&str, usize, &str)] = &[
    (
        "rust/src/optim/flat.rs",
        20,
        "worker-slot mutex locks + shard-plan invariants established by \
         FlatOptimizer::new; a poisoned slot mutex means a worker already \
         panicked mid-step, which must abort the run",
    ),
    (
        "rust/src/runtime/session.rs",
        8,
        "compile-cache/stats mutex locks; lock poisoning is itself a \
         crashed-thread symptom (the cache-hit expect became an anyhow \
         error when the lock-held-panic rule landed)",
    ),
    (
        "rust/src/coordinator/engine.rs",
        0,
        "engine and leader paths are anyhow-error clean; the recycled-ring \
         refactor replaced the last guarded pop_front expect with if-let",
    ),
    (
        "rust/src/coordinator/fused.rs",
        1,
        "accumulator is Some after the n_groups >= 1 loop (validated by \
         fused_groups)",
    ),
    (
        "rust/src/coordinator/sharding.rs",
        1,
        "min_by_key over a shard vec sized n_ranks >= 1",
    ),
    (
        "rust/src/coordinator/trainer.rs",
        1,
        "blob Option is initialized in Trainer::new and re-stored every \
         step",
    ),
    (
        "rust/src/optim/pool.rs",
        1,
        "scoped-thread join: a panicked pool worker must propagate, not \
         vanish",
    ),
    (
        "rust/src/runtime/checkpoint.rs",
        0,
        "fuzz-tested parser: the read path must NEVER panic on bad input \
         (mutated_headers_never_panic pins this)",
    ),
    (
        "rust/src/runtime/blob.rs",
        0,
        "HostBlob::load is checkpoint input surface: bounds-checked reads \
         only, no panics on untrusted bytes",
    ),
];

/// The string a waiver line must mention in docs/ANALYSIS.md's version
/// pin, e.g. `ADCP format version: 2`.
pub const DOCS_VERSION_MARK: &str = "ADCP format version:";

/// The wire-format pin docs/EXCHANGE.md must carry, e.g.
/// `q8 block size: 64` — the on-the-wire contract of the q8 rung.
pub const DOCS_Q8_MARK: &str = "q8 block size:";

pub(crate) fn in_watched(path: &str) -> bool {
    WATCHED_DIRS.iter().any(|d| path.starts_with(d))
}

// --- rule: waiver-syntax ------------------------------------------------

/// Malformed waivers (scanner parses them into empty-rule placeholders)
/// are violations: an unreadable waiver silently waives nothing.
pub fn waiver_syntax(tree: &Tree, out: &mut Vec<Finding>) {
    let known: BTreeSet<&str> = RULES.iter().map(|(id, _)| *id).collect();
    for f in &tree.sources {
        for w in &f.waivers {
            if w.rule.is_empty() {
                out.push(Finding {
                    rule: "waiver-syntax",
                    file: f.path.clone(),
                    line: w.line,
                    message: format!("malformed waiver: {}", w.reason),
                    waived: None,
                });
            } else if !known.contains(w.rule.as_str()) {
                out.push(Finding {
                    rule: "waiver-syntax",
                    file: f.path.clone(),
                    line: w.line,
                    message: format!(
                        "waiver names unknown rule {:?}",
                        w.rule
                    ),
                    waived: None,
                });
            }
        }
    }
}

// --- rule: no-unsafe ----------------------------------------------------

/// The tree is 100% safe Rust today; this locks it. A future waiver is
/// possible but must be explicit (and will show in the JSON report).
pub fn no_unsafe(tree: &Tree, out: &mut Vec<Finding>) {
    for f in &tree.sources {
        for l in &f.lines {
            if word_hit(&l.code, "unsafe") {
                out.push(super::finding(
                    f,
                    "no-unsafe",
                    l.number,
                    "`unsafe` token (the crate is #![forbid(unsafe_code)]; \
                     a waiver here must explain the soundness argument)"
                        .to_string(),
                ));
            }
        }
    }
    for root in ["rust/src/lib.rs", "rust/src/main.rs"] {
        let Some(f) = tree.sources.iter().find(|f| f.path == root) else {
            continue;
        };
        let has_forbid = f
            .lines
            .iter()
            .any(|l| l.code.contains("#![forbid(unsafe_code)]"));
        if !has_forbid {
            out.push(Finding {
                rule: "no-unsafe",
                file: root.to_string(),
                line: 0,
                message: "missing #![forbid(unsafe_code)] crate attribute"
                    .to_string(),
                waived: None,
            });
        }
    }
}

// --- rule: determinism --------------------------------------------------

/// Forbid the nondeterminism sources the parity proptests cannot see:
/// unordered iteration, threads outside the pool, clocks and float
/// reductions outside the blessed kernels.
pub fn determinism(tree: &Tree, out: &mut Vec<Finding>) {
    for f in &tree.sources {
        if !in_watched(&f.path) {
            continue;
        }
        let blessed_floats = BLESSED_FLOAT_FILES.contains(&f.path.as_str());
        for l in &f.lines {
            if l.is_test {
                continue;
            }
            for tok in UNORDERED_COLLECTIONS {
                if word_hit(&l.code, tok) {
                    out.push(super::finding(
                        f,
                        "determinism",
                        l.number,
                        format!(
                            "{tok} iteration order is nondeterministic — \
                             use BTreeMap/BTreeSet (bitwise parity across \
                             ExecPlan cells depends on stable order)"
                        ),
                    ));
                }
            }
            if f.path != THREAD_HOME && l.code.contains("thread::spawn") {
                out.push(super::finding(
                    f,
                    "determinism",
                    l.number,
                    format!(
                        "thread::spawn outside {THREAD_HOME} — reductions \
                         must consume results in rank order; waive only \
                         with a schedule-independence argument"
                    ),
                ));
            }
            for tok in CLOCK_TOKENS {
                if l.code.contains(tok) {
                    out.push(super::finding(
                        f,
                        "determinism",
                        l.number,
                        format!(
                            "{tok} is a nondeterministic input — waive if \
                             report-only, never feed it into stepping or \
                             exchange decisions"
                        ),
                    ));
                }
            }
            if !blessed_floats {
                for tok in FLOAT_TOKENS {
                    if l.code.contains(tok) {
                        out.push(super::finding(
                            f,
                            "determinism",
                            l.number,
                            format!(
                                "float op {tok} outside the blessed \
                                 kernels ({BLESSED_FLOAT_FILES:?}) — \
                                 reduction/transcendental order decides \
                                 bitwise parity"
                            ),
                        ));
                    }
                }
            }
        }
    }
}

// --- rule: panic-discipline ---------------------------------------------

/// Count unwrap()/expect() in non-test code per watched file and pin the
/// counts to [`PANIC_ALLOWLIST`]. New panics fail; removed panics emit a
/// ratchet note so the budget follows the count down.
pub fn panic_discipline(
    tree: &Tree,
    out: &mut Vec<Finding>,
    notes: &mut Vec<String>,
) {
    let budgets: BTreeMap<&str, (usize, &str)> = PANIC_ALLOWLIST
        .iter()
        .map(|(p, n, why)| (*p, (*n, *why)))
        .collect();
    for f in &tree.sources {
        if !in_watched(&f.path) {
            continue;
        }
        let count: usize = f
            .lines
            .iter()
            .filter(|l| !l.is_test)
            .map(|l| {
                l.code.matches(".unwrap()").count()
                    + l.code.matches(".expect(").count()
            })
            .sum();
        match budgets.get(f.path.as_str()) {
            Some((budget, _)) if count > *budget => out.push(Finding {
                rule: "panic-discipline",
                file: f.path.clone(),
                line: 0,
                message: format!(
                    "{count} unwrap()/expect() calls exceed the allowlist \
                     budget of {budget} — convert the new ones to anyhow \
                     errors, or raise the budget in analysis::rules with \
                     a soundness justification"
                ),
                waived: None,
            }),
            Some((budget, _)) if count < *budget => notes.push(format!(
                "panic-discipline: {} holds {count} unwrap()/expect() \
                 calls, under its budget of {budget} — ratchet the \
                 allowlist down",
                f.path
            )),
            Some(_) => {}
            None if count > 0 => out.push(Finding {
                rule: "panic-discipline",
                file: f.path.clone(),
                line: 0,
                message: format!(
                    "{count} unwrap()/expect() calls in a watched file \
                     with no allowlist entry — convert them to anyhow \
                     errors or add an annotated budget in analysis::rules"
                ),
                waived: None,
            }),
            None => {}
        }
    }
}

// --- rule: hot-path-alloc -----------------------------------------------

/// Allocation tokens whose presence inside an `ANALYZE-HOT` region is a
/// violation: the steady-state dispatch paths those regions mark must
/// not touch the heap. The `steady_state_allocs_per_step = 0` bench pin
/// is this check's runtime twin — the scan catches the token before a
/// bench run has to.
pub const HOT_ALLOC_TOKENS: &[&str] =
    &["vec!", ".to_vec()", "Vec::with_capacity", ".clone()", "Box::new"];

/// Flag allocation tokens inside `ANALYZE-HOT` regions (non-test code;
/// waivable with the standard grammar), and flag regions that are never
/// closed — an open-ended region would silently police the rest of the
/// file, so it must fail loudly instead.
pub fn hot_path_alloc(tree: &Tree, out: &mut Vec<Finding>) {
    for f in &tree.sources {
        for region in f.hot_regions() {
            let Some(end) = region.end else {
                out.push(Finding {
                    rule: "hot-path-alloc",
                    file: f.path.clone(),
                    line: region.start,
                    message: format!(
                        "ANALYZE-HOT region {:?} is never closed with \
                         ANALYZE-HOT-END",
                        region.label
                    ),
                    waived: None,
                });
                continue;
            };
            for l in &f.lines {
                if l.number <= region.start || l.number >= end || l.is_test {
                    continue;
                }
                for tok in HOT_ALLOC_TOKENS {
                    if l.code.contains(tok) {
                        out.push(super::finding(
                            f,
                            "hot-path-alloc",
                            l.number,
                            format!(
                                "{tok} inside hot region {:?} — \
                                 steady-state dispatch must be \
                                 allocation-free; hoist the buffer or \
                                 recycle it through a ring",
                                region.label
                            ),
                        ));
                    }
                }
            }
        }
    }
}

// --- rule: consistency --------------------------------------------------

/// Cross-artifact drift: bench metric names vs the baseline, `make`
/// references in CI vs Makefile targets, and the checkpoint format
/// version vs its documentation. Returns the re-derived bench-metric
/// name set (reported as machine-readable output — the independent
/// derivation of what `bench-check` gates against).
pub fn consistency(
    tree: &Tree,
    out: &mut Vec<Finding>,
    notes: &mut Vec<String>,
) -> Vec<String> {
    let metrics = bench_metrics_vs_baseline(tree, out);
    makefile_vs_ci(tree, out, notes);
    checkpoint_version_vs_docs(tree, out);
    q8_block_vs_docs(tree, out);
    metrics.into_iter().collect()
}

/// Derive the metric-name set the micro benches emit (expanding the
/// `{suffix}` dtype placeholder) and require exact two-way agreement
/// with the keys of bench/baseline.json — the same two-way contract
/// `util::bench::check_against_baseline` enforces at run time, checked
/// here without running anything.
fn bench_metrics_vs_baseline(
    tree: &Tree,
    out: &mut Vec<Finding>,
) -> BTreeSet<String> {
    let mut emitted = BTreeSet::new();
    for (path, text) in &tree.benches {
        extract_metric_names(path, text, &mut emitted, out);
    }
    if tree.benches.is_empty() {
        return emitted;
    }
    let Some(baseline_text) = tree.aux.get(AUX_BASELINE) else {
        out.push(Finding {
            rule: "consistency",
            file: AUX_BASELINE.to_string(),
            line: 0,
            message: "micro benches emit metrics but bench/baseline.json \
                      is missing"
                .to_string(),
            waived: None,
        });
        return emitted;
    };
    let baseline: BTreeSet<String> = match Json::parse(baseline_text) {
        Ok(j) => match j.as_obj() {
            Ok(o) => o.keys().cloned().collect(),
            Err(e) => {
                out.push(Finding {
                    rule: "consistency",
                    file: AUX_BASELINE.to_string(),
                    line: 0,
                    message: format!("baseline is not an object: {e}"),
                    waived: None,
                });
                return emitted;
            }
        },
        Err(e) => {
            out.push(Finding {
                rule: "consistency",
                file: AUX_BASELINE.to_string(),
                line: 0,
                message: format!("baseline does not parse: {e}"),
                waived: None,
            });
            return emitted;
        }
    };
    for name in emitted.difference(&baseline) {
        out.push(Finding {
            rule: "consistency",
            file: AUX_BASELINE.to_string(),
            line: 0,
            message: format!(
                "benches emit metric {name:?} but the baseline does not \
                 track it — bench-check will fail; add a baseline entry \
                 with tolerance/direction"
            ),
            waived: None,
        });
    }
    for name in baseline.difference(&emitted) {
        out.push(Finding {
            rule: "consistency",
            file: AUX_BASELINE.to_string(),
            line: 0,
            message: format!(
                "baseline tracks metric {name:?} but no micro bench emits \
                 it — the gate would fail on a phantom metric"
            ),
            waived: None,
        });
    }
    emitted
}

/// Pull the string literal out of every `.metric(` call in a bench
/// source. Names are literal except the dtype-suffixed pair, which the
/// benches spell `format!("...{suffix}")` with `suffix = dtype.name()`;
/// the scanner expands that placeholder over the [`Dtype`] names so the
/// derived set matches what a run would emit.
fn extract_metric_names(
    path: &str,
    text: &str,
    emitted: &mut BTreeSet<String>,
    out: &mut Vec<Finding>,
) {
    let mut from = 0usize;
    while let Some(at) = text[from..].find(".metric(") {
        let idx = from + at;
        from = idx + ".metric(".len();
        let line_start = text[..idx].rfind('\n').map_or(0, |p| p + 1);
        let line_no = text[..idx].matches('\n').count() + 1;
        if text[line_start..idx].contains("//") {
            continue; // commented-out call
        }
        // The name literal opens within the next few tokens (possibly
        // behind `&format!(`).
        let window_end = (idx + 200).min(text.len());
        let window = &text[from..window_end];
        let Some(q) = window.find('"') else {
            out.push(Finding {
                rule: "consistency",
                file: path.to_string(),
                line: line_no,
                message: ".metric( call with no derivable name literal"
                    .to_string(),
                waived: None,
            });
            continue;
        };
        let lit_body = &window[q + 1..];
        let Some(close) = lit_body.find('"') else {
            continue;
        };
        let lit = &lit_body[..close];
        if lit.contains("{suffix}") {
            for d in [Dtype::F32, Dtype::Bf16] {
                emitted.insert(lit.replace("{suffix}", d.name()));
            }
        } else if lit.contains('{') {
            out.push(Finding {
                rule: "consistency",
                file: path.to_string(),
                line: line_no,
                message: format!(
                    "metric name {lit:?} uses a placeholder the analyzer \
                     cannot expand — use a literal name or the {{suffix}} \
                     dtype convention"
                ),
                waived: None,
            });
        } else {
            emitted.insert(lit.to_string());
        }
    }
}

/// Every `make X` the CI workflow runs or the README quotes (and every
/// `$(MAKE) X` self-reference inside the Makefile) must resolve to a
/// defined target — the "CI = the Makefile, verbatim" contract,
/// machine-checked, with the README held to the same standard so its
/// quickstart never rots.
fn makefile_vs_ci(
    tree: &Tree,
    out: &mut Vec<Finding>,
    notes: &mut Vec<String>,
) {
    let Some(makefile) = tree.aux.get(AUX_MAKEFILE) else {
        return;
    };
    let targets = makefile_targets(makefile);
    if let Some(ci) = tree.aux.get(AUX_CI) {
        for (line_no, target) in make_refs(ci, "make ") {
            if !targets.contains(&target) {
                out.push(Finding {
                    rule: "consistency",
                    file: AUX_CI.to_string(),
                    line: line_no,
                    message: format!(
                        "CI runs `make {target}` but the Makefile defines \
                         no such target"
                    ),
                    waived: None,
                });
            }
        }
    } else {
        notes.push(
            "consistency: no CI workflow found — Makefile/CI cross-check \
             skipped"
                .to_string(),
        );
    }
    if let Some(readme) = tree.aux.get(AUX_README) {
        for (line_no, target) in make_refs(readme, "make ") {
            if !targets.contains(&target) {
                out.push(Finding {
                    rule: "consistency",
                    file: AUX_README.to_string(),
                    line: line_no,
                    message: format!(
                        "README references `make {target}` but the \
                         Makefile defines no such target"
                    ),
                    waived: None,
                });
            }
        }
    }
    for (line_no, target) in make_refs(makefile, "$(MAKE) ") {
        if !targets.contains(&target) {
            out.push(Finding {
                rule: "consistency",
                file: AUX_MAKEFILE.to_string(),
                line: line_no,
                message: format!(
                    "Makefile recipe invokes `$(MAKE) {target}` but no \
                     such target is defined"
                ),
                waived: None,
            });
        }
    }
}

/// Target names defined by a Makefile (rule lines, excluding variable
/// assignments, dot-targets like .PHONY, and recipe lines).
pub fn makefile_targets(text: &str) -> BTreeSet<String> {
    let mut targets = BTreeSet::new();
    for line in text.lines() {
        if line.starts_with('\t') || line.starts_with('#') {
            continue;
        }
        let Some(colon) = line.find(':') else { continue };
        if line[colon + 1..].starts_with('=') {
            continue; // `NAME := value` assignment
        }
        let name = line[..colon].trim();
        if !name.is_empty()
            && !name.starts_with('.')
            && name
                .bytes()
                .all(|b| b.is_ascii_alphanumeric() || b == b'-' || b == b'_')
        {
            targets.insert(name.to_string());
        }
    }
    targets
}

/// `(line, target)` for every `<lead>target` reference outside comments
/// (`#` starts a comment in both YAML and Make).
fn make_refs(text: &str, lead: &str) -> Vec<(usize, String)> {
    let mut refs = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("");
        let mut from = 0usize;
        while let Some(at) = line[from..].find(lead) {
            let idx = from + at;
            from = idx + lead.len();
            // `make` must start a word (not "rust-cache@v2 make"-like
            // tails of identifiers).
            if idx > 0 {
                let prev = line.as_bytes()[idx - 1];
                if prev.is_ascii_alphanumeric() || prev == b'_' || prev == b'-'
                {
                    continue;
                }
            }
            let target: String = line[from..]
                .chars()
                .take_while(|c| {
                    c.is_ascii_alphanumeric() || *c == '-' || *c == '_'
                })
                .collect();
            if !target.is_empty() {
                refs.push((i + 1, target));
            }
        }
    }
    refs
}

/// The `ADCP` on-disk version constant must match its documentation —
/// exactly the drift class of PR 5's `checkpoint_file_bytes` re-pin,
/// caught before a reviewer has to re-derive it.
fn checkpoint_version_vs_docs(tree: &Tree, out: &mut Vec<Finding>) {
    let Some(ckpt) = tree
        .sources
        .iter()
        .find(|f| f.path.ends_with("runtime/checkpoint.rs"))
    else {
        return; // fixture trees without a checkpoint module skip this
    };
    let code_version = ckpt.lines.iter().find_map(|l| {
        let tail = l.code.split("pub const VERSION: u32 =").nth(1)?;
        tail.trim().trim_end_matches(';').trim().parse::<u32>().ok()
    });
    let Some(code_version) = code_version else {
        out.push(Finding {
            rule: "consistency",
            file: ckpt.path.clone(),
            line: 0,
            message: "could not locate `pub const VERSION: u32 = N;` in \
                      the checkpoint module"
                .to_string(),
            waived: None,
        });
        return;
    };
    let Some(docs) = tree.aux.get(AUX_DOCS) else {
        out.push(Finding {
            rule: "consistency",
            file: AUX_DOCS.to_string(),
            line: 0,
            message: format!(
                "docs/ANALYSIS.md is missing — it must pin \
                 {DOCS_VERSION_MARK:?} {code_version}"
            ),
            waived: None,
        });
        return;
    };
    let documented = docs.lines().enumerate().find_map(|(i, l)| {
        let tail = l.split(DOCS_VERSION_MARK).nth(1)?;
        let num: String = tail
            .trim_start()
            .chars()
            .take_while(|c| c.is_ascii_digit())
            .collect();
        num.parse::<u32>().ok().map(|v| (i + 1, v))
    });
    match documented {
        Some((_, v)) if v == code_version => {}
        Some((line, v)) => out.push(Finding {
            rule: "consistency",
            file: AUX_DOCS.to_string(),
            line,
            message: format!(
                "docs pin ADCP format version {v} but checkpoint.rs says \
                 {code_version}"
            ),
            waived: None,
        }),
        None => out.push(Finding {
            rule: "consistency",
            file: AUX_DOCS.to_string(),
            line: 0,
            message: format!(
                "docs never state {DOCS_VERSION_MARK:?} {code_version} — \
                 add the pin so format bumps must touch the docs"
            ),
            waived: None,
        }),
    }
}

/// The q8 wire rung's block size is an on-the-wire AND on-disk contract
/// (block scales ride the exchange; error-feedback state rides ADCP v3):
/// the constant in collective.rs must match docs/EXCHANGE.md's pin —
/// the same drift class as the ADCP version check above.
fn q8_block_vs_docs(tree: &Tree, out: &mut Vec<Finding>) {
    let Some(coll) = tree
        .sources
        .iter()
        .find(|f| f.path.ends_with("coordinator/collective.rs"))
    else {
        return; // fixture trees without the collective module skip this
    };
    let code_block = coll.lines.iter().find_map(|l| {
        let tail = l.code.split("pub const Q8_BLOCK: usize =").nth(1)?;
        tail.trim().trim_end_matches(';').trim().parse::<usize>().ok()
    });
    let Some(code_block) = code_block else {
        out.push(Finding {
            rule: "consistency",
            file: coll.path.clone(),
            line: 0,
            message: "could not locate `pub const Q8_BLOCK: usize = N;` \
                      in the collective module"
                .to_string(),
            waived: None,
        });
        return;
    };
    let Some(docs) = tree.aux.get(AUX_EXCHANGE) else {
        out.push(Finding {
            rule: "consistency",
            file: AUX_EXCHANGE.to_string(),
            line: 0,
            message: format!(
                "docs/EXCHANGE.md is missing — it must pin \
                 {DOCS_Q8_MARK:?} {code_block}"
            ),
            waived: None,
        });
        return;
    };
    let documented = docs.lines().enumerate().find_map(|(i, l)| {
        let tail = l.split(DOCS_Q8_MARK).nth(1)?;
        let num: String = tail
            .trim_start()
            .chars()
            .take_while(|c| c.is_ascii_digit())
            .collect();
        num.parse::<usize>().ok().map(|v| (i + 1, v))
    });
    match documented {
        Some((_, v)) if v == code_block => {}
        Some((line, v)) => out.push(Finding {
            rule: "consistency",
            file: AUX_EXCHANGE.to_string(),
            line,
            message: format!(
                "docs pin a q8 block size of {v} but collective.rs says \
                 {code_block}"
            ),
            waived: None,
        }),
        None => out.push(Finding {
            rule: "consistency",
            file: AUX_EXCHANGE.to_string(),
            line: 0,
            message: format!(
                "docs never state {DOCS_Q8_MARK:?} {code_block} — add the \
                 pin so wire-format changes must touch the docs"
            ),
            waived: None,
        }),
    }
}
