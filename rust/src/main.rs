//! `adalomo` — the Layer-3 leader binary.
//!
//! Subcommands map to the paper's experiments (DESIGN.md §5) plus the
//! unified execution engine:
//!
//! ```text
//! adalomo scratch    --preset tiny --opt adalomo --steps 400      (§4.3, Fig 4)
//! adalomo pretrain   --preset tiny --opt adalomo --domain chinese (§4.2, Fig 2/3)
//! adalomo instruct   --preset micro --opt adalomo --steps 300     (§4.1, Table 2)
//! adalomo toy2d                                                    (App A, Fig 6)
//! adalomo memreport  [--scope table1|fig5|table8]                 (Table 1, Fig 5a)
//! adalomo throughput                                              (Fig 5b, Table 8)
//! adalomo liveness   --arch llama7b                               (§2.1 analysis)
//! adalomo fused      --preset nano --steps 5                      (fused backward demo)
//! adalomo workers    --ranks 2 --rounds 2                         (data-parallel demo)
//! adalomo train      --plan pipelined-fused [--resume ckpt]       (unified engine)
//! adalomo checkpoint-inspect --ckpt engine_ckpt.bin               (ckpt header dump)
//! adalomo hparams                                                 (Tables 3/6/7)
//! adalomo analyze    [--root DIR --json R.json --sarif R.sarif]   (static analysis)
//! adalomo info                                                    (artifacts summary)
//! ```
#![forbid(unsafe_code)]

use std::path::Path;

use anyhow::{anyhow, bail, ensure, Result};

use adalomo::config::{paper_lr, Phase, RunConfig};
use adalomo::coordinator::collective::{FabricSpec, WireCodec};
use adalomo::coordinator::engine::{Engine, ExecPlan, RankSources};
use adalomo::coordinator::fused_host;
use adalomo::coordinator::pipeline::{self, PipelineConfig};
use adalomo::coordinator::{fused, workers, Trainer};
use adalomo::data::{loader::DataLoader, Domain};
use adalomo::experiments as exp;
use adalomo::memsim::{self, liveness, memory, throughput, Arch};
use adalomo::metrics::ascii_curve;
use adalomo::optim::flat::{seeded_blob_and_grads, synthetic_layout, ShardMode};
use adalomo::optim::OptKind;
use adalomo::runtime::{checkpoint, HostBlob, Session};
use adalomo::tensor::Dtype;
use adalomo::util::cli::Args;
use adalomo::util::table::{fnum, Table};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::parse_env()?;
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "scratch" => cmd_scratch(&args),
        "pretrain" => cmd_pretrain(&args),
        "instruct" => cmd_instruct(&args),
        "toy2d" => cmd_toy2d(&args),
        "memreport" => cmd_memreport(&args),
        "throughput" => cmd_throughput(&args),
        "liveness" => cmd_liveness(&args),
        "fused" => cmd_fused(&args),
        "workers" => cmd_workers(&args),
        "train" => cmd_train(&args),
        "checkpoint-inspect" => cmd_checkpoint_inspect(&args),
        "hparams" => cmd_hparams(&args),
        "analyze" => cmd_analyze(&args),
        "bench-check" => cmd_bench_check(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}; see `adalomo help`"),
    }
}

const HELP: &str = "\
adalomo — AdaLomo (ACL 2024 Findings) full-system reproduction

USAGE: adalomo <subcommand> [--flag value ...]

  scratch     from-scratch pre-training on the C4 stand-in (Fig 4)
  pretrain    further pre-training on chinese/python_code (Fig 2/3, 7/8)
  instruct    instruction tuning + 5-benchmark suite (Table 2/5)
  toy2d       Appendix-A optimizer trajectories (Fig 6)
  memreport   analytic memory model (Table 1, Fig 5a, Table 8)
  throughput  analytic TGS model (Fig 5b, Table 8)
  liveness    gradient-liveness simulation (fused vs standard backward)
  fused       run real fused-backward group programs (nano/micro)
  workers     thread-per-rank data-parallel training demo
  train       unified engine: --plan sequential|pipelined|pipelined-fused|
              fused-host on a synthetic preset; --dtype f32|bf16 selects
              params+state storage (bf16 halves blob/checkpoint/comm
              bytes; compute stays f32); --wire f32|bf16|q8 selects the
              gradient-exchange rung (default follows the storage dtype;
              q8 adds blockwise int8 + error feedback — docs/EXCHANGE.md);
              --suspend-at K stops after step K (0 = run to completion),
              --out writes the checkpoint, --resume CKPT continues a
              saved run bitwise-identically (--ranks must then match the
              plan; membership changes go through epochs instead);
              --ranks-schedule S:R[,S:R...] declares membership epochs
              ("after step S continue with R ranks", ADCP v4);
              --fabric flat|flat:A:BW|hier:M[:IA:IBW:EA:EBW] picks the
              modeled exchange fabric (hier = two-level intra/inter-node
              rings — docs/FAULTS.md)
  checkpoint-inspect  dump an engine checkpoint header (--ckpt PATH;
              --dtype D asserts the stored dtype, --wire W the wire rung)
  hparams     the paper's hyper-parameter tables (3/6/7)
  analyze     static analysis over rust/src + cross-artifact checks:
              no-unsafe, determinism, panic-discipline, consistency,
              plus the concurrency-protocol family (lock-order,
              condvar-discipline, channel-topology, lock-held-panic)
              (--root DIR, --json REPORT.json, --sarif OUT.sarif,
              --list shows the rules, --bless-waivers prints the
              stale-waiver removal diff); exits nonzero on any
              unwaivered or stale finding
  bench-check gate measured bench metrics against bench/baseline.json
  info        artifacts + manifest summary

Common flags: --preset nano|micro|tiny|small   --opt sgd|sgd_momentum|
  sgd_variance|adamw|adafactor|lomo|adalomo|lora|adalomo_gnorm|lomo_gnorm
  --steps N --lr F --seed N --domain c4|chinese|python_code|general
  --out DIR
";

/// The (preset, opt, seed) triple every training-flavored subcommand
/// parses — one reader instead of a copy per `cmd_*`.
struct RunSpec {
    preset: String,
    opt: String,
    seed: u64,
}

fn run_spec(args: &Args, default_opt: &str) -> Result<RunSpec> {
    Ok(RunSpec {
        preset: args.str_or("preset", "nano"),
        opt: args.str_or("opt", default_opt),
        seed: args.u64_or("seed", 42)?,
    })
}

/// The base-checkpoint plumbing `pretrain` and `instruct` share: resolve
/// the cache dir, then build or load the AdamW base checkpoint.
fn base_checkpoint(
    session: &Session,
    args: &Args,
    spec: &RunSpec,
) -> Result<(String, HostBlob)> {
    let base_steps = args.usize_or("base-steps", 300)?;
    let out = args.str_or("out", "runs");
    let base = exp::ensure_base_checkpoint(
        session,
        &spec.preset,
        base_steps,
        spec.seed,
        &out,
    )?;
    Ok((out, base))
}

fn loaders(
    session: &Session,
    preset: &str,
    domain: Domain,
    seed: u64,
    steps: usize,
) -> Result<(DataLoader, DataLoader)> {
    let p = session.manifest.preset(preset)?;
    let (b, t) = (p.batch_size, p.seq_len);
    let tokens = (steps * b * t).clamp(2 * b * (t + 1), 8_000_000);
    Ok((
        DataLoader::lm(domain, seed, b, t, tokens),
        DataLoader::lm(domain, seed + 104_729, b, t, 16 * b * (t + 1)),
    ))
}

fn print_report(report: &adalomo::coordinator::TrainReport) {
    println!("{}", ascii_curve(&report.curve, 64, 10));
    println!(
        "final loss {:.4} | {:.1} steps/s | {:.0} tokens/s | wall {:.1}s",
        report.final_loss,
        report.steps as f64 / report.wall_secs,
        report.tokens_per_sec,
        report.wall_secs
    );
    for (step, ppl, acc) in &report.eval_curve {
        println!("  eval@{step}: ppl {ppl:.3} acc {acc:.3}");
    }
}

fn cmd_scratch(args: &Args) -> Result<()> {
    let session = exp::open_session()?;
    let spec = run_spec(args, "adalomo")?;
    let steps = args.usize_or("steps", 200)?;
    let mut cfg = RunConfig::new(&spec.preset, &spec.opt, Phase::Scratch, steps);
    cfg.lr = exp::effective_lr(&spec.opt, Phase::Scratch);
    cfg = cfg.override_from(args)?;
    args.finish()?;
    println!(
        "scratch pre-training: {}/{}, {steps} steps, lr {}",
        spec.preset, spec.opt, cfg.lr
    );
    let domain = Domain::parse(&cfg.domain)?;
    let (train, val) =
        loaders(&session, &spec.preset, domain, spec.seed, steps)?;
    let out = cfg.out_dir.clone();
    let mut trainer =
        Trainer::new(&session, cfg, train, Some(val))?.with_logging()?;
    let report = trainer.train()?;
    print_report(&report);
    println!("run dir: {out}/");
    Ok(())
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let session = exp::open_session()?;
    let spec = run_spec(args, "adalomo")?;
    let steps = args.usize_or("steps", 200)?;
    let domain = Domain::parse(&args.str_or("domain", "chinese"))?;
    let (out, base) = base_checkpoint(&session, args, &spec)?;
    args.finish()?;
    println!(
        "further pre-training {}/{} on {}...",
        spec.preset,
        spec.opt,
        domain.name()
    );
    let report = exp::further_pretrain(
        &session, &spec.preset, &spec.opt, domain, steps, &base, spec.seed,
        &out,
    )?;
    print_report(&report);
    Ok(())
}

fn cmd_instruct(args: &Args) -> Result<()> {
    let session = exp::open_session()?;
    let spec = run_spec(args, "adalomo")?;
    let steps = args.usize_or("steps", 200)?;
    let n_items = args.usize_or("eval-items", 24)?;
    let (out, base) = base_checkpoint(&session, args, &spec)?;
    args.finish()?;
    let outcome = exp::instruction_tune(
        &session, &spec.preset, &spec.opt, steps, &base, spec.seed, &out,
        n_items,
    )?;
    let mut table = Table::new(&format!(
        "Instruction tuning — {}/{} (paper Table 2 row)",
        spec.preset, spec.opt
    ))
    .header(&["knowledge", "reasoning", "arithmetic", "code", "writing", "avg"]);
    table.row(vec![
        fnum(outcome.suite.scores["knowledge"]),
        fnum(outcome.suite.scores["reasoning"]),
        fnum(outcome.suite.scores["arithmetic"]),
        fnum(outcome.suite.scores["code"]),
        fnum(outcome.suite.scores["writing"]),
        fnum(outcome.suite.avg),
    ]);
    table.print();
    Ok(())
}

fn cmd_toy2d(args: &Args) -> Result<()> {
    let steps = args.usize_or("steps", exp::TOY2D_STEPS)?;
    let lr = args.f32_or("lr", exp::TOY2D_LR)?;
    args.finish()?;
    let mut table = Table::new(
        "Toy 2-D landscape (paper Fig. 6): final basin per optimizer",
    )
    .header(&["optimizer", "final x", "final y", "f(x,y)", "basin"]);
    for kind in [
        OptKind::Sgd,
        OptKind::SgdMomentum,
        OptKind::SgdVariance,
        OptKind::AdamW,
    ] {
        let traj = exp::toy2d_trajectory(kind, lr, steps, exp::TOY2D_START);
        let last = traj.last().unwrap();
        table.row(vec![
            kind.name().into(),
            fnum(last.0 as f64),
            fnum(last.1 as f64),
            fnum(last.2 as f64),
            exp::toy2d_basin(&traj).into(),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_memreport(args: &Args) -> Result<()> {
    let scope = args.str_or("scope", "all");
    args.finish()?;
    if scope == "table1" || scope == "all" {
        let arch = Arch::analytic("llama7b").unwrap();
        let mut t = Table::new(
            "Paper Table 1 — model-state bytes per parameter (mixed precision)",
        )
        .header(&["method", "param", "gradient", "opt state", "total (xM)"]);
        for m in [
            memory::Method::LoRA { rank: 8 },
            memory::Method::AdamW,
            memory::Method::AdaLomo,
            memory::Method::Lomo,
            memory::Method::Adafactor,
        ] {
            let b = memory::model_state_bytes(&arch, m);
            let n = arch.n_params() as f64;
            t.row(vec![
                m.name().into(),
                fnum(b.params / n),
                fnum(b.gradients / n),
                fnum(b.optimizer_state / n),
                fnum(b.model_state() / n),
            ]);
        }
        t.print();
    }
    if scope == "table8" || scope == "fig5" || scope == "all" {
        let act = memory::calibrate();
        let mut t = Table::new(
            "Paper Table 8 / Fig 5a — total memory (GB): model vs paper",
        )
        .header(&["model", "method", "gpus", "modeled", "paper", "rel err"]);
        for &(arch, method, gpus, mb, paper_gb, _) in memsim::paper::TABLE8 {
            let setup = memory::TrainSetup {
                arch: Arch::analytic(arch).unwrap(),
                method: memory::Method::parse(method)?,
                n_gpus: gpus,
                micro_batch: mb,
                seq_len: memsim::paper::PROFILE_SEQ_LEN,
            };
            let est = memory::estimate(&setup, act).total_gb();
            t.row(vec![
                arch.into(),
                method.into(),
                gpus.to_string(),
                fnum(est),
                fnum(paper_gb),
                format!("{:+.1}%", 100.0 * (est - paper_gb) / paper_gb),
            ]);
        }
        t.print();
    }
    Ok(())
}

fn cmd_throughput(args: &Args) -> Result<()> {
    args.finish()?;
    let hw = throughput::Hardware::default();
    let eff = throughput::calibrate();
    println!(
        "calibrated: mxu_eff {:.3}, exposed_comm {:.3}",
        eff.mxu_eff, eff.exposed_comm
    );
    let mut t = Table::new(
        "Paper Table 8 / Fig 5b — throughput (tokens/GPU/s): model vs paper",
    )
    .header(&["model", "method", "gpus", "modeled", "paper", "rel err"]);
    for &(arch, method, gpus, mb, _, paper_tgs) in memsim::paper::TABLE8 {
        let setup = memory::TrainSetup {
            arch: Arch::analytic(arch).unwrap(),
            method: memory::Method::parse(method)?,
            n_gpus: gpus,
            micro_batch: mb,
            seq_len: memsim::paper::PROFILE_SEQ_LEN,
        };
        let tgs = throughput::tgs(&setup, hw, eff);
        t.row(vec![
            arch.into(),
            method.into(),
            gpus.to_string(),
            fnum(tgs),
            fnum(paper_tgs),
            format!("{:+.1}%", 100.0 * (tgs - paper_tgs) / paper_tgs),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_liveness(args: &Args) -> Result<()> {
    let arch_name = args.str_or("arch", "llama7b");
    args.finish()?;
    let arch = Arch::lookup(&arch_name)?;
    let standard = liveness::simulate(&arch, liveness::BackwardMode::Standard);
    let mut t = Table::new(&format!(
        "Gradient liveness during backward — {arch_name} (paper §2.1)"
    ))
    .header(&["mode", "peak grad bytes", "vs standard", "backward passes"]);
    let mut row = |name: &str, r: &liveness::LivenessReport| {
        t.row(vec![
            name.into(),
            format!("{:.3} GB", r.peak_bytes as f64 / memory::GB),
            format!(
                "{:.2}%",
                100.0 * r.peak_bytes as f64 / standard.peak_bytes as f64
            ),
            r.backward_passes.to_string(),
        ]);
    };
    for (name, mode) in [
        ("standard (AdamW et al.)", liveness::BackwardMode::Standard),
        ("fused (LOMO/AdaLomo)", liveness::BackwardMode::Fused),
        ("fused + grad-norm (LOMO)", liveness::BackwardMode::FusedTwoPass),
    ] {
        row(name, &liveness::simulate(&arch, mode));
    }
    // The host mirror's granularity: one whole group (layer) live at a
    // time, f32 gradients (coordinator::fused_host measures this).
    row(
        "fused host mirror (group-granular, f32)",
        &liveness::simulate_grouped(&arch, 4),
    );
    t.print();
    Ok(())
}

fn cmd_fused(args: &Args) -> Result<()> {
    let session = exp::open_session()?;
    let preset = args.str_or("preset", "nano");
    let steps = args.usize_or("steps", 5)?;
    let seed = args.u64_or("seed", 42)?;
    args.finish()?;
    let opt = "adalomo";
    let Some(groups) = fused::fused_groups(&session, &preset, opt) else {
        bail!("no fused artifacts for preset {preset} (nano/micro only)");
    };
    println!("fused backward: {groups} group programs per step");
    let sizes = fused::group_grad_sizes(&session, &preset, opt)?;
    println!(
        "per-group live gradient floats: {:?} (peak {} of {} total)",
        sizes,
        sizes.iter().max().unwrap(),
        sizes.iter().sum::<usize>()
    );
    let p = session.manifest.preset(&preset)?.clone();
    let (b, t) = (p.batch_size, p.seq_len);
    let mut loader = DataLoader::lm(Domain::C4, seed, b, t, 64 * b * (t + 1));
    let seed_buf = session.upload_i32(&[seed as i32], &[])?;
    let mut blob = session.execute_buf(
        &adalomo::runtime::Manifest::init_name(&preset, opt),
        &[&seed_buf],
    )?;
    for step in 1..=steps {
        let batch = loader.next_batch();
        let x = session.upload_i32(&batch.x, &[b, t])?;
        let y = session.upload_i32(&batch.y, &[b, t])?;
        let sched =
            session.upload_f32(&[5e-4, step as f32, 0.0, 1.0], &[4])?;
        blob =
            fused::fused_step(&session, &preset, opt, &blob, &x, &y, &sched)?;
        let m = session.execute_buf(
            &adalomo::runtime::Manifest::read_metrics_name(&preset, opt),
            &[&blob],
        )?;
        let slots = session.fetch_f32_raw(&m, 8)?;
        println!("fused step {step}: loss {:.4}", slots[0]);
    }
    println!("fused backward OK");
    Ok(())
}

fn cmd_workers(args: &Args) -> Result<()> {
    let spec = run_spec(args, "adalomo")?;
    let ranks = args.usize_or("ranks", 2)?;
    let rounds = args.usize_or("rounds", 2)?;
    let sync_every = args.usize_or("sync-every", 10)?;
    args.finish()?;
    let mut cfg =
        RunConfig::new(&spec.preset, &spec.opt, Phase::Scratch, sync_every);
    cfg.lr = exp::effective_lr(&spec.opt, Phase::Scratch);
    cfg.seed = spec.seed;
    let report = workers::run_local_sgd(
        exp::artifacts_dir(),
        cfg,
        Domain::C4,
        ranks,
        rounds,
        sync_every,
    )?;
    println!(
        "workers: {} ranks x {} rounds x {} steps",
        report.n_ranks, report.rounds, sync_every
    );
    println!("per-rank final loss: {:?}", report.per_rank_final_loss);
    println!(
        "per-rank optimizer-state sumsq (rank-local, survives rounds): {:?}",
        report.per_rank_state_sumsq
    );
    println!(
        "averaged model eval loss {:.4} | {:.0} aggregate tokens/s | wall {:.1}s",
        report.averaged_eval_loss,
        report.aggregate_tokens_per_sec,
        report.wall_secs
    );
    Ok(())
}

/// Source scale for the `train` subcommand's deterministic host-mirror
/// gradients (fixed so `--resume` reconstructs identical streams from the
/// checkpointed seed alone).
const TRAIN_SOURCE_SCALE: f32 = 0.02;

/// Host-mirror training on the unified engine: build (or resume) an
/// [`Engine`], run it to completion or to `--suspend-at`, score the
/// parameters on a fixed validation set, and write the checkpoint.
fn cmd_train(args: &Args) -> Result<()> {
    let out = args.str_or("out", "engine_ckpt.bin");
    // 0 = run to completion (a plan that suspends at step 0 would be an
    // empty run anyway).
    let suspend = args.u64_or("suspend-at", 0)?;

    if let Some(ckpt) = args.get("resume") {
        let ckpt = ckpt.to_string();
        // Optional assertions only: the checkpoint itself fixes the
        // storage dtype and wire rung a resumed run continues at.
        let want_dtype = args.get("dtype").map(Dtype::parse).transpose()?;
        let want_wire = args.get("wire").map(WireCodec::parse).transpose()?;
        let want_ranks = args
            .get("ranks")
            .map(|s| {
                s.parse::<usize>()
                    .map_err(|e| anyhow!("--ranks {s:?}: {e}"))
            })
            .transpose()?;
        // The hierarchical overlay is per-process cost model, never
        // checkpoint state: re-apply it from the flag on every resume.
        let fabric = args.get("fabric").map(FabricSpec::parse).transpose()?;
        args.finish()?;
        let mut eng = Engine::resume(Path::new(&ckpt))?;
        if let Some(r) = want_ranks {
            ensure!(
                eng.plan().n_ranks == r,
                "{ckpt} was planned for {} ranks, but --ranks asked for \
                 {r}; a silent re-plan would diverge — membership changes \
                 must be spelled as --ranks-schedule epochs (docs/FAULTS.md)",
                eng.plan().n_ranks
            );
        }
        if let Some(f) = fabric {
            eng.set_topology(f.topology());
        }
        if let Some(d) = want_dtype {
            ensure!(
                eng.plan().dtype == d,
                "{ckpt} stores {} but --dtype asked for {}",
                eng.plan().dtype.name(),
                d.name()
            );
        }
        if let Some(w) = want_wire {
            ensure!(
                eng.plan().wire == w,
                "{ckpt} exchanges over the {} wire but --wire asked for {}",
                eng.plan().wire.name(),
                w.name()
            );
        }
        println!(
            "resumed {ckpt} at step {} of {}: {}",
            eng.step(),
            eng.plan().steps,
            eng.plan().describe()
        );
        return run_engine(&mut eng, suspend, &out);
    }

    let spec = run_spec(args, "adalomo")?;
    let plan_name = args.str_or("plan", "pipelined");
    let steps = args.usize_or("steps", 8)?;
    let ranks = args.usize_or("ranks", 2)?;
    let shards = args.usize_or("shards", 2)?;
    let mode = match args.str_or("mode", "contiguous").as_str() {
        "segments" => ShardMode::Segments,
        "contiguous" => ShardMode::Contiguous,
        other => bail!("unknown shard mode {other:?} (segments|contiguous)"),
    };
    let dtype = Dtype::parse(&args.str_or("dtype", "f32"))?;
    let wire = args.get("wire").map(WireCodec::parse).transpose()?;
    let fabric = args.get("fabric").map(FabricSpec::parse).transpose()?;
    let ranks_schedule = args
        .get("ranks-schedule")
        .map(parse_ranks_schedule)
        .transpose()?
        .unwrap_or_default();
    let kind = OptKind::parse(&spec.opt)?;
    let arch = Arch::preset(&spec.preset).ok_or_else(|| {
        anyhow!(
            "no synthetic preset {:?} (nano|micro|tiny|small|base100m)",
            spec.preset
        )
    })?;
    let params = arch.param_specs();
    let specs: Vec<(&str, &[usize])> = params
        .iter()
        .map(|(n, s)| (n.as_str(), s.as_slice()))
        .collect();
    let layout = synthetic_layout(kind, &specs);
    let bucket = args
        .usize_or("bucket-elems", layout.params_len.div_ceil(8).max(1))?;
    args.finish()?;

    let (blob0, _) = seeded_blob_and_grads(&layout, spec.seed);
    let mut cfg = PipelineConfig::new(steps, bucket);
    cfg.n_shards = shards;
    cfg.dtype = dtype;
    cfg.wire = wire;
    if let Some(f) = fabric {
        cfg.fabric = f.base();
        cfg.topology = f.topology();
    }
    let mut plan = match plan_name.as_str() {
        "sequential" => ExecPlan::sequential(kind, mode, ranks, &cfg),
        "pipelined" => ExecPlan::pipelined(kind, mode, ranks, &cfg),
        "pipelined-fused" => ExecPlan::pipelined_fused(kind, mode, ranks, &cfg),
        "fused-host" => ExecPlan::fused_host(kind, mode, ranks, &cfg),
        other => bail!(
            "unknown plan {other:?} \
             (sequential|pipelined|pipelined-fused|fused-host)"
        ),
    };
    plan.seed = spec.seed;
    plan.ranks_schedule = ranks_schedule;
    let mut eng = Engine::new(&layout, &blob0, plan)?;
    eng.set_layout_key(&format!("{}/{}", spec.preset, spec.opt));
    println!(
        "train {} ({} trainable floats): {}",
        spec.preset,
        layout.params_len,
        eng.plan().describe()
    );
    run_engine(&mut eng, suspend, &out)
}

/// Parse a `--ranks-schedule STEP:RANKS[,STEP:RANKS...]` membership
/// schedule: "after completed step STEP, continue with RANKS ranks".
/// Ordering/bounds are validated by `ExecPlan::validate`.
fn parse_ranks_schedule(s: &str) -> Result<Vec<(u64, u32)>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let (step, ranks) = part.split_once(':').ok_or_else(|| {
            anyhow!(
                "--ranks-schedule entries are STEP:RANKS, got {part:?}"
            )
        })?;
        let step: u64 = step
            .trim()
            .parse()
            .map_err(|e| anyhow!("--ranks-schedule step {step:?}: {e}"))?;
        let ranks: u32 = ranks
            .trim()
            .parse()
            .map_err(|e| anyhow!("--ranks-schedule ranks {ranks:?}: {e}"))?;
        out.push((step, ranks));
    }
    Ok(out)
}

/// Reconstruct the deterministic rank sources a plan (or one membership
/// epoch of it) trains on — the canonical [`fused_host::plan_sources`]
/// reconstruction, so `--resume` rebuilds byte-identical streams from
/// the checkpointed plan alone.
fn run_engine(eng: &mut Engine, suspend: u64, out: &str) -> Result<()> {
    if suspend > 0 {
        eng.suspend_at(suspend);
    }
    let extents = eng.group_extents();
    let report = eng.run_elastic(|seg_plan: &ExecPlan| -> RankSources {
        fused_host::plan_sources(
            seg_plan,
            extents.clone(),
            TRAIN_SOURCE_SCALE,
        )
    })?;
    println!(
        "ran {} steps x {} buckets: exposed {:.3}ms vs compute+comm \
         {:.3}ms ({:.2}x overlap); peak live grad {} of {} bytes",
        report.steps,
        report.n_buckets,
        report.exposed_secs * 1e3,
        (report.compute_secs + report.comm_secs) * 1e3,
        report.overlap_efficiency,
        report.peak_live_grad_bytes,
        report.full_grad_bytes
    );
    println!(
        "{} storage, {} wire: blob {} bytes; modeled exchange {} bytes/step \
         (peak tile {} bytes)",
        report.dtype.name(),
        report.wire.name(),
        report.blob_bytes,
        report.comm_bytes_per_step,
        report.peak_comm_bytes
    );
    // Fixed-validation-set score of the parameter region (the host
    // stand-in eval the suspend/resume tests pin bitwise; bf16 params
    // are widened exactly, so the loss is a function of the stored bits).
    let params_len = eng.layout().params_len;
    let mut val = DataLoader::lm(Domain::C4, 9_999, 2, 32, 8_000);
    let blob = eng.blob();
    let loss = pipeline::host_eval_loss(&blob[..params_len], &mut val, 4);
    println!("fixed-val-set eval loss {loss:.6e}");
    eng.save(Path::new(out))?;
    println!(
        "checkpoint: {out} (step {} of {}{})",
        eng.step(),
        eng.plan().steps,
        if eng.is_finished() { "" } else { ", suspended" }
    );
    Ok(())
}

fn cmd_checkpoint_inspect(args: &Args) -> Result<()> {
    let path = args.str_or("ckpt", "engine_ckpt.bin");
    let want_dtype = args.get("dtype").map(Dtype::parse).transpose()?;
    let want_wire = args.get("wire").map(WireCodec::parse).transpose()?;
    args.finish()?;
    let ck = checkpoint::load(Path::new(&path))?;
    let plan = ExecPlan::from_record(&ck.plan)?;
    let bytes = std::fs::metadata(&path)?.len();
    let dtype = ck.layout.storage_dtype()?;
    if let Some(d) = want_dtype {
        ensure!(
            dtype == d,
            "{path} stores {} but --dtype asked to verify {}",
            dtype.name(),
            d.name()
        );
    }
    if let Some(w) = want_wire {
        ensure!(
            plan.wire == w,
            "{path} exchanges over the {} wire but --wire asked to \
             verify {}",
            plan.wire.name(),
            w.name()
        );
    }
    println!("checkpoint {path}");
    println!(
        "  format v{}..v{} reader | {bytes} bytes on disk",
        checkpoint::V1,
        checkpoint::VERSION
    );
    println!(
        "  layout {} | {} elements ({} params, {} segments)",
        ck.layout_key,
        ck.layout.blob_len,
        ck.layout.params_len,
        ck.layout.segments.len()
    );
    println!(
        "  storage {} | params+state+metrics {} bytes in memory \
         (f32 would be {})",
        dtype.name(),
        ck.blob.storage_bytes(),
        ck.layout.blob_len * 4
    );
    println!(
        "  wire {} | error-feedback ranks {}",
        plan.wire.name(),
        ck.ef.len()
    );
    println!(
        "  ranks {} (epoch 0){} | resumes with {}",
        plan.n_ranks,
        if plan.ranks_schedule.is_empty() {
            String::from(" | fixed membership")
        } else {
            format!(
                " | membership epochs {:?}",
                plan.ranks_schedule
            )
        },
        plan.ranks_for_step(ck.step.saturating_add(1))
    );
    println!(
        "  step {} of {} ({})",
        ck.step,
        plan.steps,
        if ck.step >= plan.steps as u64 {
            "finished"
        } else {
            "suspended mid-run"
        }
    );
    println!("  plan: {}", plan.describe());
    println!("  source seed {}", plan.seed);
    Ok(())
}

fn cmd_hparams(args: &Args) -> Result<()> {
    args.finish()?;
    for (title, phase, opts) in [
        (
            "Paper Table 3 — instruction-tuning LRs",
            Phase::Instruct,
            vec!["lora", "adamw", "lomo", "adalomo"],
        ),
        (
            "Paper Table 6 — further pre-training LRs",
            Phase::FurtherPretrain,
            vec!["adamw", "adalomo"],
        ),
        (
            "Paper Table 7 — from-scratch pre-training LRs",
            Phase::Scratch,
            vec!["sgd", "adafactor", "adamw", "adalomo"],
        ),
    ] {
        let mut t = Table::new(title).header(&[
            "optimizer",
            "paper LR",
            "scaled LR (this repo)",
        ]);
        for opt in opts {
            t.row(vec![
                opt.into(),
                format!("{:.0e}", paper_lr(opt, phase)),
                format!("{:.0e}", exp::effective_lr(opt, phase)),
            ]);
        }
        t.print();
    }
    Ok(())
}

/// The `make analyze` entry point: scan the tree, print every finding,
/// write the JSON report, and exit nonzero if any violation is not
/// explicitly waived. docs/ANALYSIS.md documents the rules and the
/// `ANALYZE-WAIVE` comment syntax.
fn cmd_analyze(args: &Args) -> Result<()> {
    let root = args.str_or("root", ".");
    let json_path = args.get("json").map(str::to_string);
    let sarif_path = args.get("sarif").map(str::to_string);
    let list = args.bool("list");
    let bless_waivers = args.bool("bless-waivers");
    args.finish()?;
    if list {
        let mut t = Table::new("analyze — rule registry")
            .header(&["rule", "checks that"]);
        for (id, desc) in adalomo::analysis::rules::RULES {
            t.row(vec![(*id).into(), (*desc).into()]);
        }
        t.print();
        return Ok(());
    }
    let report = adalomo::analysis::run(Path::new(&root))?;
    if bless_waivers {
        if report.stale_waivers.is_empty() {
            println!("no stale waivers — nothing to remove");
            return Ok(());
        }
        for (file, line, rule) in &report.stale_waivers {
            println!("--- {file}:{line} (waives {rule:?}, no finding)");
            let text = std::fs::read_to_string(Path::new(&root).join(file))
                .unwrap_or_default();
            if let Some(l) =
                line.checked_sub(1).and_then(|i| text.lines().nth(i))
            {
                println!("-{l}");
            }
        }
        bail!(
            "{} stale waiver(s) — delete the lines above (or just the \
             trailing comment where the waiver shares a line with code)",
            report.stale_waivers.len()
        );
    }
    if let Some(path) = &json_path {
        std::fs::write(path, report.to_json().to_string())
            .map_err(|e| anyhow!("writing {path}: {e}"))?;
    }
    if let Some(path) = &sarif_path {
        std::fs::write(path, report.to_sarif().to_string())
            .map_err(|e| anyhow!("writing {path}: {e}"))?;
    }
    let violations = report.violations();
    for f in &violations {
        if f.line > 0 {
            println!("VIOLATION [{}] {}:{}: {}", f.rule, f.file, f.line, f.message);
        } else {
            println!("VIOLATION [{}] {}: {}", f.rule, f.file, f.message);
        }
    }
    for f in report.findings.iter().filter(|f| f.waived.is_some()) {
        println!(
            "waived    [{}] {}:{}: {}",
            f.rule,
            f.file,
            f.line,
            f.waived.as_deref().unwrap_or("")
        );
    }
    for n in &report.notes {
        println!("note      {n}");
    }
    println!(
        "analyze: {} files, {} bench metrics derived, {} violation(s), \
         {} waived",
        report.files_scanned,
        report.bench_metrics.len(),
        violations.len(),
        report.waived_count()
    );
    if !violations.is_empty() {
        bail!(
            "{} unwaivered finding(s) — fix them or add \
             `// ANALYZE-WAIVE(rule): reason` (see docs/ANALYSIS.md)",
            violations.len()
        );
    }
    Ok(())
}

fn cmd_bench_check(args: &Args) -> Result<()> {
    let current_path = args.str_or("current", "BENCH_pipeline.json");
    let baseline_path = args.str_or("baseline", "bench/baseline.json");
    let bless = args.bool("bless");
    args.finish()?;
    let read = |path: &str| -> Result<adalomo::util::json::Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {path}: {e}"))?;
        adalomo::util::json::Json::parse(&text)
            .map_err(|e| anyhow!("parsing {path}: {e}"))
    };
    let current = read(&current_path)?;
    let baseline = read(&baseline_path)?;
    if bless {
        // Intentional re-baseline: refresh every value, keep each
        // metric's stated tolerance/direction.
        let blessed =
            adalomo::util::bench::bless_baseline(&current, &baseline)?;
        std::fs::write(&baseline_path, blessed.to_string())
            .map_err(|e| anyhow!("writing {baseline_path}: {e}"))?;
        println!("blessed {baseline_path} with values from {current_path}");
        return Ok(());
    }
    let rows =
        adalomo::util::bench::check_against_baseline(&current, &baseline)?;
    let mut t = Table::new(&format!(
        "Bench regression gate — {current_path} vs {baseline_path}"
    ))
    .header(&["metric", "baseline", "current", "ratio", "tol", "verdict"]);
    for r in &rows {
        t.row(vec![
            format!("{} ({})", r.name, r.direction),
            fnum(r.baseline),
            fnum(r.current),
            format!("{:.3}x", r.current / r.baseline),
            format!("{:.0}%", r.tolerance * 100.0),
            if r.failed { "REGRESSED".into() } else { "ok".to_string() },
        ]);
    }
    t.print();
    let n_failed = rows.iter().filter(|r| r.failed).count();
    if n_failed > 0 {
        bail!(
            "{n_failed} tracked metric(s) regressed beyond tolerance; for \
             an intentional shift re-baseline with `make bench-bless` \
             (keeps each metric's stated tolerance/direction)"
        );
    }
    println!("bench gate OK: {} tracked metrics within tolerance", rows.len());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    args.finish()?;
    if !exp::artifacts_available() {
        println!("artifacts/ not built — run `make artifacts`");
        return Ok(());
    }
    let session = exp::open_session()?;
    println!(
        "platform: {} ({} devices)",
        session.client().platform_name(),
        session.client().device_count()
    );
    println!("kernel impl: {}", session.manifest.kernel_impl);
    let mut t = Table::new("Presets").header(&[
        "preset", "params", "layers", "d_model", "batch", "seq", "entries",
    ]);
    for (name, p) in &session.manifest.presets {
        let n_entries = session.entries_for_preset(name).len();
        t.row(vec![
            name.clone(),
            p.n_params.to_string(),
            p.n_layers.to_string(),
            p.d_model.to_string(),
            p.batch_size.to_string(),
            p.seq_len.to_string(),
            n_entries.to_string(),
        ]);
    }
    t.print();
    println!("total entries: {}", session.manifest.entries.len());
    Ok(())
}
