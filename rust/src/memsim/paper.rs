//! Paper measurement fixtures (Table 8 / Fig. 5): the ground truth the
//! simulator calibrates against and the benches compare with.

/// One Table-8 row: (model, method, n_gpus, micro_batch, memory_gb, tgs).
pub const TABLE8: &[(&str, &str, usize, usize, f64, f64)] = &[
    ("llama7b", "adamw", 4, 8, 169.4, 3169.4),
    ("llama7b", "adafactor", 4, 8, 144.3, 3169.5),
    ("llama7b", "lora", 4, 8, 70.6, 3344.6),
    ("llama7b", "lomo", 4, 8, 59.6, 3228.2),
    ("llama7b", "adalomo", 4, 8, 59.6, 2997.4),
    ("llama13b", "adamw", 8, 4, 320.7, 1679.6),
    ("llama13b", "adafactor", 8, 4, 272.3, 1683.4),
    ("llama13b", "lora", 8, 4, 110.0, 1829.8),
    ("llama13b", "lomo", 8, 4, 94.4, 1659.9),
    ("llama13b", "adalomo", 8, 4, 95.8, 1456.3),
    ("llama30b", "adamw", 16, 4, 786.2, 728.6),
    ("llama30b", "adafactor", 16, 4, 665.0, 726.5),
    ("llama30b", "lora", 16, 4, 303.7, 811.6),
    ("llama30b", "lomo", 16, 4, 264.3, 669.1),
    ("llama30b", "adalomo", 16, 4, 272.8, 589.0),
    ("llama65b", "adamw", 32, 2, 1532.6, 349.1),
    ("llama65b", "adafactor", 32, 2, 1289.4, 341.1),
    ("llama65b", "lora", 32, 2, 510.5, 405.7),
    ("llama65b", "lomo", 32, 2, 473.8, 303.3),
    ("llama65b", "adalomo", 32, 2, 507.7, 238.1),
];

/// Sequence length used in the profiling runs (paper Appendix F setup).
pub const PROFILE_SEQ_LEN: usize = 2048;

/// Table 2 (instruction tuning) benchmark averages per method, LLaMA-7B —
/// used by the Table-2 bench to report paper-vs-measured *orderings*.
pub const TABLE2_7B_AVG: &[(&str, f64)] = &[
    ("none", 18.1),
    ("lora", 26.5),
    ("adamw", 29.1),
    ("lomo", 24.0),
    ("adalomo", 30.8),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table8_complete() {
        assert_eq!(TABLE8.len(), 20);
        // AdaLomo memory is within 8% of LOMO at every size (paper claim).
        for size in ["llama7b", "llama13b", "llama30b", "llama65b"] {
            let get = |m: &str| {
                TABLE8
                    .iter()
                    .find(|r| r.0 == size && r.1 == m)
                    .map(|r| r.4)
                    .unwrap()
            };
            let (lomo, adalomo, adamw) =
                (get("lomo"), get("adalomo"), get("adamw"));
            assert!((adalomo - lomo) / lomo < 0.08, "{size}");
            assert!(adamw / adalomo > 2.5, "{size}");
        }
    }
}
