//! Throughput (TGS — tokens per GPU per second) model for Fig. 5b /
//! Table 8.
//!
//! step_time = compute + exposed communication + optimizer update, with the
//! A800+NVLink constants of the paper's testbed. Two efficiency scalars
//! (MXU efficiency, exposed-communication fraction) are calibrated against
//! the Table-8 TGS column by coordinate descent; the *method-dependent*
//! terms — communication volume, update passes, the second backward of
//! grad-norm LOMO — are first-principles, which is what fixes the ordering
//! LoRA > AdamW ≈ Adafactor ≈ LOMO > AdaLomo.

use super::arch::Arch;
use super::memory::{Method, TrainSetup};
use super::paper;

/// Hardware constants (A800-80GB SXM + NVLink).
#[derive(Debug, Clone, Copy)]
pub struct Hardware {
    /// Peak dense bf16 FLOP/s per GPU.
    pub peak_flops: f64,
    /// Effective interconnect bandwidth per GPU, bytes/s.
    pub link_bw: f64,
    /// Effective HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Fixed per-matrix launch/sync overhead, seconds (fused updates issue
    /// one small op per weight matrix; scaled by sqrt(n_gpus) for
    /// cross-rank statistic syncs).
    pub launch_overhead: f64,
    /// Effective bandwidth of eager (hook-fused, per-matrix) update passes,
    /// bytes/s — far below HBM peak due to small-op overhead. Calibrated.
    pub eager_bw: f64,
}

impl Default for Hardware {
    fn default() -> Self {
        Hardware {
            peak_flops: 312e12,
            link_bw: 170e9,
            hbm_bw: 1.6e12,
            launch_overhead: 120e-6,
            eager_bw: 45e9,
        }
    }
}

/// Calibrated efficiency scalars.
#[derive(Debug, Clone, Copy)]
pub struct Efficiency {
    /// Achieved fraction of peak FLOP/s (kernel + pipeline efficiency).
    pub mxu_eff: f64,
    /// Fraction of communication NOT overlapped with compute.
    pub exposed_comm: f64,
}

impl Default for Efficiency {
    fn default() -> Self {
        calibrate()
    }
}

/// Communication volume per GPU per step, bytes (ZeRO-3 ring collectives:
/// all-gather params for forward + for backward, reduce-scatter grads).
fn comm_bytes(arch: &Arch, method: Method) -> f64 {
    let n = arch.n_params() as f64;
    let weights = 2.0 * n; // bf16
    match method {
        // params fwd + params bwd + grad reduce-scatter.
        Method::AdamW | Method::Adafactor | Method::AdafactorPure => {
            3.0 * weights
        }
        // Base weights still gathered twice; adapter grads are tiny.
        Method::LoRA { rank } => {
            2.0 * weights + 2.0 * arch.lora_params(rank) as f64
        }
        // Fused backward reduces each matrix's gradient as it is produced:
        // same total volume, but many small messages -> 30% efficiency
        // penalty on the gradient reduction leg.
        Method::Lomo | Method::AdaLomo => 2.0 * weights + 2.0 * n / 0.7,
    }
}

/// Optimizer-update time per step, seconds.
///
/// Two regimes, mirroring the implementations the paper profiles:
/// * sharded fused-kernel steps (apex AdamW / HF Adafactor): stream the
///   shard's state through HBM once;
/// * hook-fused eager updates (LOMO/AdaLomo): under ZeRO-3 the *full*
///   gradient of each matrix exists on every rank right after its backward
///   op, and the update (for AdaLomo: factor EMAs + reconstruction + the
///   grouped-norm statistics, three streaming passes) runs eagerly over it
///   before the reduce-scatter frees it. AdaLomo additionally pays one
///   cross-rank sync per weight tensor for the factored-moment / norm
///   statistics; collective latency grows ~sqrt(G) on the ring. This full-N
///   eager term is what widens the LOMO-AdaLomo gap from ~7% at 7B/4GPU to
///   ~20% at 65B/32GPU in Table 8.
fn update_time(arch: &Arch, method: Method, n_gpus: usize, hw: Hardware) -> f64 {
    let n_shard = arch.n_params() as f64 / n_gpus as f64;
    let n_full = arch.n_params() as f64;
    let tensors = arch.param_specs().len() as f64;
    let sync = hw.launch_overhead * (n_gpus as f64).sqrt();
    match method {
        // read p16,g16,m32,v32,master32; write p16,m32,v32,master32.
        Method::AdamW => 26.0 * n_shard / hw.hbm_bw,
        Method::Adafactor => 22.0 * n_shard / hw.hbm_bw,
        Method::AdafactorPure => 14.0 * n_shard / hw.hbm_bw,
        Method::LoRA { rank } => {
            26.0 * arch.lora_params(rank) as f64 / n_gpus as f64 / hw.hbm_bw
        }
        // One eager pass: read g (bf16), write the param shard.
        Method::Lomo => 2.0 * n_full / hw.eager_bw + tensors * sync,
        // Three eager passes (moments, statistics, apply) + per-tensor
        // grouped-norm sync.
        Method::AdaLomo => {
            3.0 * 2.0 * n_full / hw.eager_bw + 2.0 * tensors * sync
        }
    }
}

/// Predicted step time, seconds.
pub fn step_time(setup: &TrainSetup, hw: Hardware, eff: Efficiency) -> f64 {
    let tokens = (setup.micro_batch * setup.seq_len) as f64;
    let compute = setup.arch.flops_per_token() * tokens
        / (hw.peak_flops * eff.mxu_eff);
    let comm = comm_bytes(&setup.arch, setup.method) / hw.link_bw
        * eff.exposed_comm;
    let update = update_time(&setup.arch, setup.method, setup.n_gpus, hw);
    compute + comm + update
}

/// Tokens per GPU per second.
pub fn tgs(setup: &TrainSetup, hw: Hardware, eff: Efficiency) -> f64 {
    let tokens = (setup.micro_batch * setup.seq_len) as f64;
    tokens / step_time(setup, hw, eff)
}

/// Coordinate-descent fit of (mxu_eff, exposed_comm) to Table 8's TGS
/// column (log-space squared error).
pub fn calibrate() -> Efficiency {
    let hw = Hardware::default();
    let loss = |eff: Efficiency| -> f64 {
        paper::TABLE8
            .iter()
            .map(|&(arch, method, n_gpus, mb, _, tgs_paper)| {
                let setup = TrainSetup {
                    arch: Arch::analytic(arch).unwrap(),
                    method: Method::parse(method).unwrap(),
                    n_gpus,
                    micro_batch: mb,
                    seq_len: paper::PROFILE_SEQ_LEN,
                };
                let pred = tgs(&setup, hw, eff);
                (pred.ln() - tgs_paper.ln()).powi(2)
            })
            .sum()
    };
    let mut best = Efficiency { mxu_eff: 0.45, exposed_comm: 0.3 };
    let mut best_loss = loss(best);
    for _ in 0..100 {
        let mut improved = false;
        for (dm, dc) in
            [(1.05, 1.0), (0.95, 1.0), (1.0, 1.1), (1.0, 0.9)]
        {
            let cand = Efficiency {
                mxu_eff: (best.mxu_eff * dm).clamp(0.05, 0.95),
                exposed_comm: (best.exposed_comm * dc).clamp(0.01, 1.0),
            };
            let l = loss(cand);
            if l < best_loss {
                best = cand;
                best_loss = l;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(arch: &str, method: Method, g: usize, mb: usize) -> TrainSetup {
        TrainSetup {
            arch: Arch::analytic(arch).unwrap(),
            method,
            n_gpus: g,
            micro_batch: mb,
            seq_len: paper::PROFILE_SEQ_LEN,
        }
    }

    #[test]
    fn ordering_matches_paper_at_7b() {
        let hw = Hardware::default();
        let eff = calibrate();
        let t = |m| tgs(&setup("llama7b", m, 4, 8), hw, eff);
        let (lora, adamw, lomo, adalomo) = (
            t(Method::LoRA { rank: 8 }),
            t(Method::AdamW),
            t(Method::Lomo),
            t(Method::AdaLomo),
        );
        assert!(lora > adamw, "LoRA fastest (less communication)");
        assert!(adalomo < lomo, "AdaLomo pays extra update passes");
        // Paper: AdaLomo ~5-10% below LOMO at 7B; "same level" overall.
        let gap = (lomo - adalomo) / lomo;
        assert!(gap > 0.01 && gap < 0.25, "gap {gap}");
    }

    #[test]
    fn calibrated_within_band_of_table8() {
        let hw = Hardware::default();
        let eff = calibrate();
        for &(arch, method, g, mb, _, tgs_paper) in paper::TABLE8 {
            let pred = tgs(
                &setup(arch, Method::parse(method).unwrap(), g, mb),
                hw,
                eff,
            );
            let rel = (pred - tgs_paper).abs() / tgs_paper;
            assert!(
                rel < 0.60,
                "{arch}/{method}: pred {pred:.0} vs paper {tgs_paper} ({rel:.2})"
            );
        }
    }

    #[test]
    fn tgs_decreases_with_model_size() {
        let hw = Hardware::default();
        let eff = Efficiency::default();
        let t7 = tgs(&setup("llama7b", Method::AdaLomo, 4, 8), hw, eff);
        let t65 = tgs(&setup("llama65b", Method::AdaLomo, 32, 2), hw, eff);
        assert!(t7 > 4.0 * t65);
    }

    #[test]
    fn grad_norm_two_pass_halves_throughput() {
        // The LOMO + gradient-norm variant runs backward twice: the paper's
        // motivation for grouped normalization ("nearly doubles speed").
        let hw = Hardware::default();
        let eff = Efficiency::default();
        let s = setup("llama7b", Method::Lomo, 4, 8);
        let one = step_time(&s, hw, eff);
        // Second backward ~= extra compute-dominated pass (2/3 of fwd+bwd
        // FLOPs) + the same exposed gradient communication.
        let two = one
            + setup("llama7b", Method::Lomo, 4, 8)
                .arch
                .flops_per_token()
                * (8.0 * paper::PROFILE_SEQ_LEN as f64)
                * (2.0 / 3.0)
                / (hw.peak_flops * eff.mxu_eff);
        let slowdown = two / one;
        assert!(slowdown > 1.4 && slowdown < 2.1, "{slowdown}");
    }
}
