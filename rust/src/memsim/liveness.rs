//! Gradient-liveness simulation: a discrete-event walk of the backward
//! schedule, reproducing paper §2.1's argument that LOMO/AdaLomo keep at
//! most two consecutive parameter gradients alive while standard optimizers
//! accumulate all of them (and gradient-norm clipping forces a second
//! backward pass for LOMO — the time cost AdaLomo's grouped normalization
//! removes).

use super::arch::Arch;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackwardMode {
    /// Gradients accumulate until the optimizer step (AdamW/Adafactor).
    Standard,
    /// Fused update during backward; gradient freed once the next one is
    /// computed (LOMO/AdaLomo).
    Fused,
    /// Fused + global gradient-norm: two backward walks, same liveness
    /// (LOMO + grad-norm, paper §2.1).
    FusedTwoPass,
}

#[derive(Debug, Clone)]
pub struct LivenessReport {
    /// Peak simultaneously-live gradient bytes.
    pub peak_bytes: usize,
    /// Live gradient bytes after each backward event.
    pub curve: Vec<usize>,
    /// Number of backward walks (1, or 2 for the grad-norm variant).
    pub backward_passes: usize,
}

/// Walk the parameter list in backward order (reverse of forward: head
/// first, embed last), tracking gradient buffer liveness. Gradients are
/// bf16 (2 bytes/element), matching the paper's mixed-precision setup.
pub fn simulate(arch: &Arch, mode: BackwardMode) -> LivenessReport {
    let specs = arch.param_specs();
    let sizes: Vec<usize> = specs
        .iter()
        .rev()
        .map(|(_, s)| 2 * s.iter().product::<usize>())
        .collect();

    let mut live = 0usize;
    let mut peak = 0usize;
    let mut curve = Vec::with_capacity(sizes.len());
    match mode {
        BackwardMode::Standard => {
            for &sz in &sizes {
                live += sz;
                peak = peak.max(live);
                curve.push(live);
            }
        }
        BackwardMode::Fused | BackwardMode::FusedTwoPass => {
            // Gradient i stays alive until gradient i+1 has been computed
            // (it may feed that computation), then is freed by the fused
            // update: at most two are simultaneously live.
            let mut prev = 0usize;
            for &sz in &sizes {
                live = prev + sz;
                peak = peak.max(live);
                curve.push(live);
                prev = sz;
            }
        }
    }
    LivenessReport {
        peak_bytes: peak,
        curve,
        backward_passes: if mode == BackwardMode::FusedTwoPass { 2 } else { 1 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arch() -> Arch {
        Arch::analytic("llama7b").unwrap()
    }

    #[test]
    fn standard_peak_is_full_model() {
        let r = simulate(&arch(), BackwardMode::Standard);
        assert_eq!(r.peak_bytes, 2 * arch().n_params());
        assert_eq!(r.backward_passes, 1);
    }

    #[test]
    fn fused_peak_is_two_matrices() {
        let r = simulate(&arch(), BackwardMode::Fused);
        // Peak = the two largest *adjacent* gradients; bounded by twice the
        // largest matrix and tiny relative to the model.
        assert!(r.peak_bytes <= 2 * 2 * arch().max_matrix());
        assert!(r.peak_bytes < 2 * arch().n_params() / 20);
    }

    #[test]
    fn two_pass_same_memory_double_time() {
        let fused = simulate(&arch(), BackwardMode::Fused);
        let two = simulate(&arch(), BackwardMode::FusedTwoPass);
        assert_eq!(fused.peak_bytes, two.peak_bytes);
        assert_eq!(two.backward_passes, 2);
    }

    #[test]
    fn curve_monotone_for_standard() {
        let r = simulate(&arch(), BackwardMode::Standard);
        assert!(r.curve.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*r.curve.last().unwrap(), r.peak_bytes);
    }

    #[test]
    fn fused_curve_never_exceeds_peak_and_oscillates() {
        let r = simulate(&arch(), BackwardMode::Fused);
        assert!(r.curve.iter().all(|&b| b <= r.peak_bytes));
        // Liveness must come back down after big matrices (not monotone).
        assert!(r.curve.windows(2).any(|w| w[1] < w[0]));
    }
}
