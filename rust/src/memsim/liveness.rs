//! Gradient-liveness simulation: a discrete-event walk of the backward
//! schedule, reproducing paper §2.1's argument that LOMO/AdaLomo keep at
//! most two consecutive parameter gradients alive while standard optimizers
//! accumulate all of them (and gradient-norm clipping forces a second
//! backward pass for LOMO — the time cost AdaLomo's grouped normalization
//! removes).

use super::arch::Arch;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackwardMode {
    /// Gradients accumulate until the optimizer step (AdamW/Adafactor).
    Standard,
    /// Fused update during backward; gradient freed once the next one is
    /// computed (LOMO/AdaLomo).
    Fused,
    /// Fused + global gradient-norm: two backward walks, same liveness
    /// (LOMO + grad-norm, paper §2.1).
    FusedTwoPass,
}

#[derive(Debug, Clone)]
pub struct LivenessReport {
    /// Peak simultaneously-live gradient bytes.
    pub peak_bytes: usize,
    /// Live gradient bytes after each backward event.
    pub curve: Vec<usize>,
    /// Number of backward walks (1, or 2 for the grad-norm variant).
    pub backward_passes: usize,
}

/// Walk the parameter list in backward order (reverse of forward: head
/// first, embed last), tracking gradient buffer liveness. Gradients are
/// bf16 (2 bytes/element), matching the paper's mixed-precision setup.
pub fn simulate(arch: &Arch, mode: BackwardMode) -> LivenessReport {
    let specs = arch.param_specs();
    let sizes: Vec<usize> = specs
        .iter()
        .rev()
        .map(|(_, s)| 2 * s.iter().product::<usize>())
        .collect();

    let mut live = 0usize;
    let mut peak = 0usize;
    let mut curve = Vec::with_capacity(sizes.len());
    match mode {
        BackwardMode::Standard => {
            for &sz in &sizes {
                live += sz;
                peak = peak.max(live);
                curve.push(live);
            }
        }
        BackwardMode::Fused | BackwardMode::FusedTwoPass => {
            // Gradient i stays alive until gradient i+1 has been computed
            // (it may feed that computation), then is freed by the fused
            // update: at most two are simultaneously live.
            let mut prev = 0usize;
            for &sz in &sizes {
                live = prev + sz;
                peak = peak.max(live);
                curve.push(live);
                prev = sz;
            }
        }
    }
    LivenessReport {
        peak_bytes: peak,
        curve,
        backward_passes: if mode == BackwardMode::FusedTwoPass { 2 } else { 1 },
    }
}

/// Per-group gradient sizes in f32 elements for the *group-granular*
/// fused-backward walk (G = L + 2 groups, backward order: head block,
/// layers L-1..0, embedding) — the analytic twin of
/// `optim::flat::FlatOptimizer::group_grad_sizes` (engine-derived) and
/// `coordinator::fused::group_grad_sizes` (manifest-derived).
pub fn group_elems(arch: &Arch) -> Vec<usize> {
    let specs = arch.param_specs();
    let size = |name: &str| -> usize {
        specs
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| s.iter().product())
            .unwrap_or(0)
    };
    let mut groups = vec![size("head") + size("final_norm")];
    for l in (0..arch.n_layers).rev() {
        let p = format!("l{l}.");
        groups.push(
            specs
                .iter()
                .filter(|(n, _)| n.starts_with(&p))
                .map(|(_, s)| s.iter().product::<usize>())
                .sum(),
        );
    }
    groups.push(size("embed"));
    groups
}

/// Liveness of the group-granular fused-backward walk, as executed by the
/// host mirror (`coordinator::fused_host`): each group's gradient is freed
/// by its optimizer step *before* the next group is produced, so exactly
/// one group is ever live and the peak is the largest group. Coarser than
/// [`BackwardMode::Fused`]'s per-parameter walk (which keeps two adjacent
/// parameter gradients live), but the same §2.1 argument: peak gradient
/// memory is O(one layer), not O(model). `bytes_per_elem` is 4 for the
/// host mirror's f32 gradients (the device walks above use bf16 = 2).
pub fn simulate_grouped(arch: &Arch, bytes_per_elem: usize) -> LivenessReport {
    let curve: Vec<usize> = group_elems(arch)
        .iter()
        .map(|&e| e * bytes_per_elem)
        .collect();
    LivenessReport {
        peak_bytes: curve.iter().copied().max().unwrap_or(0),
        curve,
        backward_passes: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arch() -> Arch {
        Arch::analytic("llama7b").unwrap()
    }

    #[test]
    fn standard_peak_is_full_model() {
        let r = simulate(&arch(), BackwardMode::Standard);
        assert_eq!(r.peak_bytes, 2 * arch().n_params());
        assert_eq!(r.backward_passes, 1);
    }

    #[test]
    fn fused_peak_is_two_matrices() {
        let r = simulate(&arch(), BackwardMode::Fused);
        // Peak = the two largest *adjacent* gradients; bounded by twice the
        // largest matrix and tiny relative to the model.
        assert!(r.peak_bytes <= 2 * 2 * arch().max_matrix());
        assert!(r.peak_bytes < 2 * arch().n_params() / 20);
    }

    #[test]
    fn two_pass_same_memory_double_time() {
        let fused = simulate(&arch(), BackwardMode::Fused);
        let two = simulate(&arch(), BackwardMode::FusedTwoPass);
        assert_eq!(fused.peak_bytes, two.peak_bytes);
        assert_eq!(two.backward_passes, 2);
    }

    #[test]
    fn curve_monotone_for_standard() {
        let r = simulate(&arch(), BackwardMode::Standard);
        assert!(r.curve.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*r.curve.last().unwrap(), r.peak_bytes);
    }

    #[test]
    fn grouped_walk_covers_model_once() {
        let a = arch();
        let groups = group_elems(&a);
        // G = L + 2: head block, one per layer, embedding.
        assert_eq!(groups.len(), a.n_layers + 2);
        assert_eq!(groups.iter().sum::<usize>(), a.n_params());
        assert!(groups.iter().all(|&g| g > 0));
    }

    #[test]
    fn grouped_peak_is_one_group_and_beats_the_half_layer_bound() {
        let a = arch();
        let r = simulate_grouped(&a, 4);
        assert_eq!(r.backward_passes, 1);
        assert_eq!(r.curve.len(), a.n_layers + 2);
        assert_eq!(
            r.peak_bytes,
            *r.curve.iter().max().unwrap(),
            "peak is exactly the largest group"
        );
        // The acceptance bound the host mirror is held to: peak live
        // gradient < full image / (L/2).
        let full = 4 * a.n_params();
        assert!(
            r.peak_bytes < full / (a.n_layers / 2),
            "peak {} vs full {full} (L = {})",
            r.peak_bytes,
            a.n_layers
        );
        // Coarser granularity can only cost memory vs the per-parameter
        // fused walk at the same element width.
        let fine = simulate(&a, BackwardMode::Fused);
        assert!(r.peak_bytes >= 2 * fine.peak_bytes);
    }

    #[test]
    fn fused_curve_never_exceeds_peak_and_oscillates() {
        let r = simulate(&arch(), BackwardMode::Fused);
        assert!(r.curve.iter().all(|&b| b <= r.peak_bytes));
        // Liveness must come back down after big matrices (not monotone).
        assert!(r.curve.windows(2).any(|w| w[1] < w[0]));
    }
}
