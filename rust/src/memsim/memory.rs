//! Model-state memory accounting (paper Table 1, Fig. 5a, Table 8 memory).
//!
//! Exact closed forms for parameters / gradients / optimizer state under
//! the paper's mixed-precision + ZeRO-3 setup, plus a two-coefficient
//! activation/overhead term calibrated against the paper's own Table 8
//! (see [`calibrate`]). All byte counts are cluster totals (the paper
//! reports pynvml sums across GPUs).

use anyhow::Result;

use super::arch::Arch;
use super::paper;

pub const GB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Training method — the paper's five-way comparison set.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    AdamW,
    /// As profiled in the paper (HF-style config retaining the first
    /// moment; see DESIGN.md §Faithfulness — the pure momentum-less
    /// variant is `AdafactorPure`).
    Adafactor,
    AdafactorPure,
    LoRA { rank: usize },
    Lomo,
    AdaLomo,
}

pub const PROFILE_METHODS: [Method; 5] = [
    Method::AdamW,
    Method::Adafactor,
    Method::LoRA { rank: 8 },
    Method::Lomo,
    Method::AdaLomo,
];

impl Method {
    pub fn parse(name: &str) -> Result<Method> {
        Ok(match name {
            "adamw" | "adam" => Method::AdamW,
            "adafactor" => Method::Adafactor,
            "adafactor_pure" => Method::AdafactorPure,
            "lora" => Method::LoRA { rank: 8 },
            "lomo" => Method::Lomo,
            "adalomo" => Method::AdaLomo,
            other => anyhow::bail!("unknown method {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::AdamW => "adamw",
            Method::Adafactor => "adafactor",
            Method::AdafactorPure => "adafactor_pure",
            Method::LoRA { .. } => "lora",
            Method::Lomo => "lomo",
            Method::AdaLomo => "adalomo",
        }
    }

    pub fn fused_backward(&self) -> bool {
        matches!(self, Method::Lomo | Method::AdaLomo)
    }
}

/// A profiling scenario (one Table-8 row).
#[derive(Debug, Clone)]
pub struct TrainSetup {
    pub arch: Arch,
    pub method: Method,
    pub n_gpus: usize,
    pub micro_batch: usize,
    pub seq_len: usize,
}

/// Cluster-total memory, bytes.
#[derive(Debug, Clone, Copy)]
pub struct MemoryBreakdown {
    pub params: f64,
    pub gradients: f64,
    pub optimizer_state: f64,
    pub activations: f64,
    pub overhead: f64,
}

impl MemoryBreakdown {
    pub fn model_state(&self) -> f64 {
        self.params + self.gradients + self.optimizer_state
    }

    pub fn total(&self) -> f64 {
        self.model_state() + self.activations + self.overhead
    }

    pub fn total_gb(&self) -> f64 {
        self.total() / GB
    }
}

/// Activation bytes per (micro-batch token x layer x d_model) and per-GPU
/// runtime overhead — the two calibrated coefficients. Defaults come from
/// `calibrate()` over Table 8 and are re-derived by the Table-8 bench.
#[derive(Debug, Clone, Copy)]
pub struct ActModel {
    pub act_coeff: f64,
    pub gpu_overhead: f64,
}

impl Default for ActModel {
    fn default() -> Self {
        calibrate()
    }
}

/// Bytes of factored second moment for AdaLomo/Adafactor: fp32 (m + n) per
/// matrix, full fp32 vector state for 1-D parameters.
fn factored_state_bytes(arch: &Arch) -> f64 {
    let mut floats = 0usize;
    for (_, shape) in arch.param_specs() {
        floats += if shape.len() == 2 {
            shape[0] + shape[1]
        } else {
            shape.iter().product()
        };
    }
    4.0 * floats as f64
}

/// Exact model-state terms (no calibration). `two pass gradient norm`
/// (the LOMO baseline's normalization, paper §2.1) does not change peak
/// memory — only time — so it has no term here.
pub fn model_state_bytes(arch: &Arch, method: Method) -> MemoryBreakdown {
    let n = arch.n_params() as f64;
    // bf16 weights for everyone (mixed precision).
    let params = 2.0 * n;
    let (gradients, optimizer_state) = match method {
        // bf16 grads + fp32 master/m/v (DeepSpeed mixed-precision Adam).
        Method::AdamW => (2.0 * n, 12.0 * n),
        // Paper-profiled Adafactor: master + first moment + factored v.
        Method::Adafactor => {
            (2.0 * n, 8.0 * n + factored_state_bytes(arch))
        }
        // Shazeer-Stern Adafactor: master + factored v only.
        Method::AdafactorPure => {
            (2.0 * n, 4.0 * n + factored_state_bytes(arch))
        }
        Method::LoRA { rank } => {
            let a = arch.lora_params(rank) as f64;
            // Adapter grads bf16 + fp32 master/m/v for adapters only.
            (2.0 * a, 12.0 * a)
        }
        // Fused backward: at most two consecutive parameter gradients are
        // live (paper §2.1) -> O(1) in model size.
        Method::Lomo => (2.0 * 2.0 * arch.max_matrix() as f64, 0.0),
        Method::AdaLomo => (
            2.0 * 2.0 * arch.max_matrix() as f64,
            factored_state_bytes(arch),
        ),
    };
    MemoryBreakdown {
        params,
        gradients,
        optimizer_state,
        activations: 0.0,
        overhead: 0.0,
    }
}

/// Full memory estimate for a profiling scenario.
pub fn estimate(setup: &TrainSetup, act: ActModel) -> MemoryBreakdown {
    let mut b = model_state_bytes(&setup.arch, setup.method);
    let per_gpu_tokens = (setup.micro_batch * setup.seq_len) as f64;
    b.activations = act.act_coeff
        * per_gpu_tokens
        * (setup.arch.n_layers * setup.arch.d_model) as f64
        * setup.n_gpus as f64;
    b.overhead = act.gpu_overhead * setup.n_gpus as f64;
    b
}

/// Least-squares fit of (act_coeff, gpu_overhead) to the Table-8 residuals
/// total_measured - model_state_exact = act_coeff * X + gpu_overhead * G.
pub fn calibrate() -> ActModel {
    // Normal equations for 2 unknowns.
    let (mut xx, mut xg, mut gg, mut xy, mut gy) = (0.0, 0.0, 0.0, 0.0, 0.0);
    for &(arch_name, method, n_gpus, micro_batch, mem_gb, _) in paper::TABLE8 {
        let arch = Arch::analytic(arch_name).unwrap();
        let method = Method::parse(method).unwrap();
        let state = model_state_bytes(&arch, method).model_state();
        let y = mem_gb * GB - state;
        let x = (micro_batch * paper::PROFILE_SEQ_LEN) as f64
            * (arch.n_layers * arch.d_model) as f64
            * n_gpus as f64;
        let g = n_gpus as f64;
        xx += x * x;
        xg += x * g;
        gg += g * g;
        xy += x * y;
        gy += g * y;
    }
    let det = xx * gg - xg * xg;
    let act_coeff = (xy * gg - gy * xg) / det;
    let gpu_overhead = (gy * xx - xy * xg) / det;
    ActModel { act_coeff, gpu_overhead }
}

/// Paper Table 1 closed form: total model-state memory in units of M
/// (bytes per parameter), for the three-way LoRA/AdamW/AdaLomo comparison.
pub fn table1_bytes_per_param(arch: &Arch, method: Method) -> f64 {
    let b = model_state_bytes(arch, method);
    b.model_state() / arch.n_params() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arch7b() -> Arch {
        Arch::analytic("llama7b").unwrap()
    }

    #[test]
    fn table1_closed_forms() {
        let a = arch7b();
        // AdamW: 2M + 2M + 12M = 16M bytes (paper Table 1 row).
        let adamw = table1_bytes_per_param(&a, Method::AdamW);
        assert!((adamw - 16.0).abs() < 1e-6, "{adamw}");
        // AdaLomo: ~2M (factored state + 2-matrix grads are O(sqrt)).
        let adalomo = table1_bytes_per_param(&a, Method::AdaLomo);
        assert!(adalomo > 2.0 && adalomo < 2.2, "{adalomo}");
        // LoRA: ~2M.
        let lora = table1_bytes_per_param(&a, Method::LoRA { rank: 8 });
        assert!(lora > 2.0 && lora < 2.2, "{lora}");
        // LOMO strictly below AdaLomo (no optimizer state at all).
        assert!(
            table1_bytes_per_param(&a, Method::Lomo) < adalomo,
            "lomo should be the floor"
        );
    }

    #[test]
    fn adalomo_state_is_40pct_of_adafactor_claim() {
        // Paper §1: "AdaLomo's memory usage accounts for ~40% of Adafactor".
        // Model-state comparison at 7B: AdaLomo ~2.05M vs Adafactor-as-
        // profiled 12M+rc; the paper's 40% figure refers to total measured
        // memory (59.6/144.3 = 41%) — check against the fixture.
        let rows = paper::TABLE8;
        let get = |m: &str| {
            rows.iter().find(|r| r.0 == "llama7b" && r.1 == m).unwrap().4
        };
        let ratio = get("adalomo") / get("adafactor");
        assert!((ratio - 0.41).abs() < 0.02, "{ratio}");
        // And our model reproduces a ratio in the same band.
        let act = calibrate();
        let mk = |method| {
            estimate(
                &TrainSetup {
                    arch: arch7b(),
                    method,
                    n_gpus: 4,
                    micro_batch: 8,
                    seq_len: paper::PROFILE_SEQ_LEN,
                },
                act,
            )
            .total()
        };
        let model_ratio = mk(Method::AdaLomo) / mk(Method::Adafactor);
        assert!(model_ratio > 0.30 && model_ratio < 0.55, "{model_ratio}");
    }

    #[test]
    fn calibrated_model_matches_table8_shape() {
        let act = calibrate();
        assert!(act.act_coeff > 0.0, "activation coefficient must be +");
        let mut max_rel_err: f64 = 0.0;
        for &(arch_name, method, n_gpus, micro_batch, mem_gb, _) in
            paper::TABLE8
        {
            let est = estimate(
                &TrainSetup {
                    arch: Arch::analytic(arch_name).unwrap(),
                    method: Method::parse(method).unwrap(),
                    n_gpus,
                    micro_batch,
                    seq_len: paper::PROFILE_SEQ_LEN,
                },
                act,
            );
            let rel = (est.total_gb() - mem_gb).abs() / mem_gb;
            max_rel_err = max_rel_err.max(rel);
        }
        // Two fitted coefficients over 20 measurements: demand < 30%
        // worst-case (the paper's own numbers carry allocator noise; the
        // bench reports the full residual table).
        assert!(max_rel_err < 0.30, "worst relative error {max_rel_err}");
    }

    #[test]
    fn ordering_invariants_any_arch() {
        // AdaLomo <= Adafactor <= AdamW and AdaLomo close to LOMO, for
        // every analytic architecture.
        let act = calibrate();
        for name in ["llama1b1", "llama7b", "llama13b", "llama30b", "llama65b"]
        {
            let mk = |method| {
                estimate(
                    &TrainSetup {
                        arch: Arch::analytic(name).unwrap(),
                        method,
                        n_gpus: 8,
                        micro_batch: 4,
                        seq_len: 2048,
                    },
                    act,
                )
                .total()
            };
            let (adamw, adaf, lora, lomo, adalomo) = (
                mk(Method::AdamW),
                mk(Method::Adafactor),
                mk(Method::LoRA { rank: 8 }),
                mk(Method::Lomo),
                mk(Method::AdaLomo),
            );
            assert!(adalomo < adaf && adaf < adamw, "{name}");
            assert!(adalomo < lora * 1.05, "{name}: comparable to LoRA");
            assert!((adalomo - lomo) / lomo < 0.10, "{name}: close to LOMO");
        }
    }

    #[test]
    fn gradient_liveness_is_o1_for_fused() {
        let a = arch7b();
        let lomo = model_state_bytes(&a, Method::Lomo).gradients;
        let adamw = model_state_bytes(&a, Method::AdamW).gradients;
        // Two embed-sized matrices vs the full model.
        assert!(lomo < adamw / 20.0);
    }
}
