//! Analytic memory + throughput simulator.
//!
//! The paper's memory results (Table 1, Fig. 5, Table 8) were measured with
//! pynvml on 4-32 A800 GPUs under DeepSpeed ZeRO-3 — hardware this repo
//! substitutes per DESIGN.md §4. The substitution is an analytic model with
//! the same physics:
//!
//! * **model state** (exact): parameter/gradient/optimizer-state bytes per
//!   method under mixed precision — the closed forms of Table 1;
//! * **gradient liveness** (exact): a discrete-event walk of the backward
//!   schedule ([`liveness`]) showing LOMO/AdaLomo's O(1) gradient memory vs
//!   the O(N) of standard optimizers;
//! * **activations + runtime overhead** (calibrated): two coefficients fit
//!   against the paper's own Table 8 measurements ([`paper`] fixture);
//! * **throughput** (calibrated shape): compute/communication/update-pass
//!   time model reproducing the TGS ordering of Fig. 5b.

pub mod arch;
pub mod liveness;
pub mod memory;
pub mod paper;
pub mod throughput;

pub use arch::Arch;
pub use memory::{MemoryBreakdown, Method, TrainSetup};
