//! Transformer architecture descriptions.
//!
//! Mirrors `python/compile/model.py::param_specs` exactly (the pytest suite
//! and `integration_memsim` cross-check counts through the manifest), and
//! adds the analytic LLaMA presets used by the paper's evaluation.

/// LLaMA-family architecture (RMSNorm + RoPE + SwiGLU, no biases).
#[derive(Debug, Clone, PartialEq)]
pub struct Arch {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
}

impl Arch {
    pub fn new(
        name: &str,
        vocab: usize,
        d_model: usize,
        n_layers: usize,
        n_heads: usize,
        d_ff: usize,
    ) -> Arch {
        Arch {
            name: name.to_string(),
            vocab,
            d_model,
            n_layers,
            n_heads,
            d_ff,
        }
    }

    /// The paper's model ladder. Parameter counts come out at 1.09B, 6.74B,
    /// 13.0B, 32.5B and 65.3B — within 1% of the advertised sizes.
    pub fn analytic(name: &str) -> Option<Arch> {
        let (d, l, h, f, v) = match name {
            "llama1b1" => (2048, 22, 32, 5632, 32000),
            "llama7b" => (4096, 32, 32, 11008, 32000),
            "llama13b" => (5120, 40, 40, 13824, 32000),
            "llama30b" => (6656, 60, 52, 17920, 32000),
            "llama65b" => (8192, 80, 64, 22016, 32000),
            _ => return None,
        };
        Some(Arch::new(name, v, d, l, h, f))
    }

    /// Experiment presets runnable through the AOT artifacts.
    pub fn preset(name: &str) -> Option<Arch> {
        let (v, d, l, h, f) = match name {
            "nano" => (256, 64, 2, 4, 176),
            "micro" => (256, 128, 4, 4, 352),
            "tiny" => (256, 256, 6, 8, 704),
            "small" => (256, 512, 8, 8, 1408),
            "base100m" => (256, 768, 12, 12, 2048),
            _ => return None,
        };
        Some(Arch::new(name, v, d, l, h, f))
    }

    pub fn lookup(name: &str) -> anyhow::Result<Arch> {
        Self::preset(name)
            .or_else(|| Self::analytic(name))
            .ok_or_else(|| anyhow::anyhow!("unknown architecture {name:?}"))
    }

    /// Parameter matrices in forward order: (name, shape). Must stay in
    /// lockstep with `python/compile/model.py::param_specs`.
    pub fn param_specs(&self) -> Vec<(String, Vec<usize>)> {
        let (d, f, v) = (self.d_model, self.d_ff, self.vocab);
        let mut out: Vec<(String, Vec<usize>)> =
            vec![("embed".into(), vec![v, d])];
        for l in 0..self.n_layers {
            let p = format!("l{l}.");
            out.push((format!("{p}attn_norm"), vec![d]));
            out.push((format!("{p}wq"), vec![d, d]));
            out.push((format!("{p}wk"), vec![d, d]));
            out.push((format!("{p}wv"), vec![d, d]));
            out.push((format!("{p}wo"), vec![d, d]));
            out.push((format!("{p}ffn_norm"), vec![d]));
            out.push((format!("{p}w_gate"), vec![d, f]));
            out.push((format!("{p}w_up"), vec![d, f]));
            out.push((format!("{p}w_down"), vec![f, d]));
        }
        out.push(("final_norm".into(), vec![d]));
        out.push(("head".into(), vec![d, v]));
        out
    }

    pub fn n_params(&self) -> usize {
        self.param_specs()
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }

    /// Largest single parameter matrix (elements) — the unit of LOMO's
    /// "two consecutive gradients" liveness bound.
    pub fn max_matrix(&self) -> usize {
        self.param_specs()
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .max()
            .unwrap_or(0)
    }

    /// LoRA adapter parameter count (rank-r on wq/wv, as in model.py).
    pub fn lora_params(&self, rank: usize) -> usize {
        self.n_layers * 2 * (2 * self.d_model * rank)
    }

    /// FLOPs per token for fwd+bwd (the standard 6N approximation).
    pub fn flops_per_token(&self) -> f64 {
        6.0 * self.n_params() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama_param_counts_match_advertised() {
        let cases = [
            ("llama1b1", 1.0e9, 1.35e9),  // full MHA (no GQA) -> 1.26B
            ("llama7b", 6.5e9, 7.0e9),
            ("llama13b", 12.5e9, 13.5e9),
            ("llama30b", 31.0e9, 34.0e9),
            ("llama65b", 63.0e9, 67.0e9),
        ];
        for (name, lo, hi) in cases {
            let n = Arch::analytic(name).unwrap().n_params() as f64;
            assert!(n > lo && n < hi, "{name}: {n}");
        }
    }

    #[test]
    fn llama7b_has_723ish_weight_tensors() {
        // Paper §2.1 quotes 723 weight matrices / 82 layers for 65B.
        let a = Arch::analytic("llama65b").unwrap();
        assert_eq!(a.param_specs().len(), 80 * 9 + 3);
    }

    #[test]
    fn preset_counts() {
        let nano = Arch::preset("nano").unwrap();
        // embed + head: 2*256*64; per layer: 4*64^2 + 3*64*176 + 2*64; final.
        assert!(nano.n_params() > 100_000 && nano.n_params() < 150_000);
        assert!(Arch::preset("bogus").is_none());
    }

    #[test]
    fn max_matrix_is_embed_for_llama() {
        let a = Arch::analytic("llama7b").unwrap();
        assert_eq!(a.max_matrix(), 32000 * 4096);
    }

    #[test]
    fn lora_param_count() {
        let a = Arch::analytic("llama7b").unwrap();
        // 32 layers * 2 targets * 2 matrices * d*rank
        assert_eq!(a.lora_params(8), 32 * 2 * 2 * 4096 * 8);
    }
}
