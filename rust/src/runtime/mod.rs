//! PJRT runtime: loads the AOT artifacts (HLO text + manifest) and executes
//! them on the CPU PJRT client. This is the only module that touches the
//! `xla` crate; everything above it works with plain `f32`/`i32` host
//! buffers and opaque device handles.
//!
//! Hot-path contract (see DESIGN.md §6): every entry returns a single
//! non-tuple array, so a training step is
//! `blob_buffer = session.execute_buf(train_step, [blob_buffer, x, y, sched])`
//! — the multi-hundred-KB state blob never leaves the device; only the
//! 32-byte metrics slice is fetched (via the `read_metrics_*` entry) when
//! the coordinator wants to log.

pub mod blob;
pub mod checkpoint;
pub mod manifest;
pub mod session;

pub use blob::{BlobPartsMut, HostBlob, TypedBlob};
pub use manifest::{Entry, Layout, Manifest, PresetInfo, Segment};
pub use session::Session;
