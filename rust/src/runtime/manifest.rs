//! `artifacts/manifest.json` — the contract between `python -m compile.aot`
//! (which writes it) and the Rust runtime (which trusts it for every shape,
//! dtype, blob offset and entry-point name).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, ensure, Context, Result};

use crate::tensor::Dtype;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

#[derive(Debug, Clone)]
pub struct Entry {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub preset: Option<String>,
    pub opt: Option<String>,
    pub layout_key: Option<String>,
    pub inputs: Vec<IoSpec>,
    pub output_shape: Vec<usize>,
    /// fused_group entries: (group index, total groups).
    pub group: Option<(usize, usize)>,
}

/// One blob segment (mirrors python/compile/layout.py).
#[derive(Debug, Clone, PartialEq)]
pub struct Segment {
    pub name: String,
    pub kind: String, // param | frozen | state | metric
    pub shape: Vec<usize>,
    /// Blob offset in ELEMENTS (dtype-independent).
    pub offset: usize,
    /// Element count (dtype-independent; storage bytes are
    /// `size * dtype.bytes()`).
    pub size: usize,
    /// Storage dtype of this region's elements. [`Dtype::F32`] unless the
    /// layout was retagged via [`Layout::with_storage_dtype`]; metric
    /// segments always stay f32 (they hold exact counters).
    pub dtype: Dtype,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Layout {
    pub blob_len: usize,
    pub params_len: usize,
    pub segments: Vec<Segment>,
}

impl Layout {
    pub fn metrics_offset(&self) -> usize {
        self.segments
            .iter()
            .find(|s| s.kind == "metric")
            .map(|s| s.offset)
            .unwrap_or(self.blob_len)
    }

    pub fn segment(&self, name: &str) -> Option<&Segment> {
        self.segments.iter().find(|s| s.name == name)
    }

    /// Trainable parameter segments (excludes frozen/state/metrics).
    pub fn trainable(&self) -> impl Iterator<Item = &Segment> {
        self.segments.iter().filter(|s| s.kind == "param")
    }

    /// All optimizer-state segments attached to `param` (layout.py naming
    /// convention: `{param}@{suffix}`), in layout order.
    pub fn state_segments<'a>(
        &'a self,
        param: &'a str,
    ) -> impl Iterator<Item = &'a Segment> {
        self.segments.iter().filter(move |s| {
            s.kind == "state"
                && s.name.len() > param.len() + 1
                && s.name.starts_with(param)
                && s.name.as_bytes()[param.len()] == b'@'
        })
    }

    /// One optimizer-state segment by suffix (`m`, `v`, `r`, `c`).
    pub fn state_segment(&self, param: &str, suffix: &str) -> Option<&Segment> {
        self.segment(&format!("{param}@{suffix}"))
    }

    /// Length of the shardable region: parameters + optimizer state. The
    /// trailing metrics region is replicated coordinator state and never
    /// sharded (same rule as `coordinator::sharding`).
    pub fn shardable_len(&self) -> usize {
        self.metrics_offset()
    }

    /// Segments overlapping the half-open blob range `[lo, hi)` — the
    /// bucket-granular view the async pipeline uses to map an exchange
    /// bucket onto the tensors it touches (and, via the LAST overlapping
    /// bucket, completes). An empty range (`lo >= hi`) overlaps nothing.
    pub fn segments_in_range(
        &self,
        lo: usize,
        hi: usize,
    ) -> impl Iterator<Item = &Segment> {
        self.segments
            .iter()
            .filter(move |s| lo < hi && s.offset < hi && s.offset + s.size > lo)
    }

    /// The uniform storage [`Dtype`] of the shardable (params + optimizer
    /// state) region. Metric segments must stay f32 and the non-metric
    /// segments must agree — the blob codecs store the prefix at one
    /// width, so a mixed tagging is a reportable error, not a layout.
    pub fn storage_dtype(&self) -> Result<Dtype> {
        let mut dtype: Option<Dtype> = None;
        for s in &self.segments {
            if s.kind == "metric" {
                ensure!(
                    s.dtype == Dtype::F32,
                    "metric segment {} must stay f32 (exact counters)",
                    s.name
                );
            } else {
                match dtype {
                    None => dtype = Some(s.dtype),
                    Some(d) => ensure!(
                        d == s.dtype,
                        "mixed storage dtypes: segment {} is {}, expected {}",
                        s.name,
                        s.dtype.name(),
                        d.name()
                    ),
                }
            }
        }
        Ok(dtype.unwrap_or(Dtype::F32))
    }

    /// Clone with every param/frozen/state segment tagged `dtype` (metric
    /// segments always stay f32). Offsets and sizes are in elements and
    /// do not move — only the storage width changes.
    pub fn with_storage_dtype(&self, dtype: Dtype) -> Layout {
        let mut out = self.clone();
        for s in out.segments.iter_mut() {
            if s.kind != "metric" {
                s.dtype = dtype;
            }
        }
        out
    }
}

#[derive(Debug, Clone)]
pub struct PresetInfo {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch_size: usize,
    pub n_params: usize,
    pub fused_groups: usize,
    pub opts: Vec<String>,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub kernel_impl: String,
    pub presets: BTreeMap<String, PresetInfo>,
    pub layouts: BTreeMap<String, Layout>,
    pub entries: BTreeMap<String, Entry>,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()?.iter().map(|v| v.as_usize()).collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "cannot read {path:?} — run `make artifacts` first"
            )
        })?;
        let j = Json::parse(&text).context("manifest.json parse")?;

        let mut presets = BTreeMap::new();
        for (name, p) in j.get("presets")?.as_obj()? {
            presets.insert(
                name.clone(),
                PresetInfo {
                    name: name.clone(),
                    vocab: p.get("vocab")?.as_usize()?,
                    d_model: p.get("d_model")?.as_usize()?,
                    n_layers: p.get("n_layers")?.as_usize()?,
                    n_heads: p.get("n_heads")?.as_usize()?,
                    d_ff: p.get("d_ff")?.as_usize()?,
                    seq_len: p.get("seq_len")?.as_usize()?,
                    batch_size: p.get("batch_size")?.as_usize()?,
                    n_params: p.get("n_params")?.as_usize()?,
                    fused_groups: p.get("fused_groups")?.as_usize()?,
                    opts: p
                        .get("opts")?
                        .as_arr()?
                        .iter()
                        .map(|o| Ok(o.as_str()?.to_string()))
                        .collect::<Result<Vec<_>>>()?,
                },
            );
        }

        let mut layouts = BTreeMap::new();
        for (key, l) in j.get("layouts")?.as_obj()? {
            let segments = l
                .get("segments")?
                .as_arr()?
                .iter()
                .map(|s| {
                    // Manifests written before the dtype axis carry no
                    // tag; they are all-f32 by construction.
                    let dtype = match s.opt("dtype") {
                        Some(d) => Dtype::parse(d.as_str()?)?,
                        None => Dtype::F32,
                    };
                    Ok(Segment {
                        name: s.get("name")?.as_str()?.to_string(),
                        kind: s.get("kind")?.as_str()?.to_string(),
                        shape: shape_of(s.get("shape")?)?,
                        offset: s.get("offset")?.as_usize()?,
                        size: s.get("size")?.as_usize()?,
                        dtype,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            layouts.insert(
                key.clone(),
                Layout {
                    blob_len: l.get("blob_len")?.as_usize()?,
                    params_len: l.get("params_len")?.as_usize()?,
                    segments,
                },
            );
        }

        let mut entries = BTreeMap::new();
        for (name, e) in j.get("entries")?.as_obj()? {
            let inputs = e
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(|i| {
                    Ok(IoSpec {
                        name: i.get("name")?.as_str()?.to_string(),
                        shape: shape_of(i.get("shape")?)?,
                        dtype: i.get("dtype")?.as_str()?.to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let group = match (e.opt("group"), e.opt("n_groups")) {
                (Some(g), Some(n)) => Some((g.as_usize()?, n.as_usize()?)),
                _ => None,
            };
            entries.insert(
                name.clone(),
                Entry {
                    name: name.clone(),
                    file: e.get("file")?.as_str()?.to_string(),
                    kind: e.get("kind")?.as_str()?.to_string(),
                    preset: e
                        .opt("preset")
                        .and_then(|p| p.as_str().ok())
                        .map(String::from),
                    opt: e
                        .opt("opt")
                        .and_then(|p| p.as_str().ok())
                        .map(String::from),
                    layout_key: e
                        .opt("layout")
                        .and_then(|p| p.as_str().ok())
                        .map(String::from),
                    inputs,
                    output_shape: shape_of(e.get("output")?.get("shape")?)?,
                    group,
                },
            );
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            kernel_impl: j
                .opt("kernel_impl")
                .and_then(|k| k.as_str().ok())
                .unwrap_or("pallas")
                .to_string(),
            presets,
            layouts,
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("no AOT entry {name:?} in manifest"))
    }

    pub fn preset(&self, name: &str) -> Result<&PresetInfo> {
        self.presets
            .get(name)
            .ok_or_else(|| anyhow!("no preset {name:?} in manifest"))
    }

    pub fn layout(&self, key: &str) -> Result<&Layout> {
        self.layouts
            .get(key)
            .ok_or_else(|| anyhow!("no layout {key:?} in manifest"))
    }

    pub fn hlo_path(&self, entry: &Entry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    // --- canonical entry names (shared with aot.py) ------------------------

    pub fn train_step_name(preset: &str, opt: &str) -> String {
        format!("train_step_{preset}_{opt}")
    }

    pub fn init_name(preset: &str, opt: &str) -> String {
        // gnorm variants share the base optimizer's layout & init.
        let base = opt.strip_suffix("_gnorm").unwrap_or(opt);
        format!("init_{preset}_{base}")
    }

    pub fn layout_key(preset: &str, opt: &str) -> String {
        let base = opt.strip_suffix("_gnorm").unwrap_or(opt);
        format!("{preset}/{base}")
    }

    pub fn read_metrics_name(preset: &str, opt: &str) -> String {
        let base = opt.strip_suffix("_gnorm").unwrap_or(opt);
        format!("read_metrics_{preset}_{base}")
    }

    pub fn extract_params_name(preset: &str, opt: &str) -> String {
        let base = opt.strip_suffix("_gnorm").unwrap_or(opt);
        format!("extract_params_{preset}_{base}")
    }

    pub fn eval_name(preset: &str) -> String {
        format!("eval_{preset}")
    }

    pub fn fused_name(preset: &str, opt: &str, group: usize) -> String {
        format!("fused_{preset}_{opt}_g{group}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests need `make artifacts` to have run; they are the
    /// manifest-side half of the cross-layer contract.
    fn manifest() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn loads_and_has_nano() {
        let Some(m) = manifest() else { return };
        let p = m.preset("nano").unwrap();
        assert_eq!(p.d_model, 64);
        assert_eq!(p.vocab, 256);
        assert!(m.entry("train_step_nano_adalomo").is_ok());
        assert!(m.entry("bogus").is_err());
    }

    #[test]
    fn layouts_are_consistent() {
        let Some(m) = manifest() else { return };
        for (key, layout) in &m.layouts {
            // Segments tile the blob exactly.
            let mut off = 0;
            for s in &layout.segments {
                assert_eq!(s.offset, off, "{key}/{}", s.name);
                assert_eq!(
                    s.size,
                    s.shape.iter().product::<usize>().max(1),
                    "{key}/{}",
                    s.name
                );
                off += s.size;
            }
            assert_eq!(off, layout.blob_len, "{key}");
            // Params region is a prefix.
            assert!(layout.params_len <= layout.blob_len);
            assert_eq!(layout.metrics_offset() + 8, layout.blob_len);
        }
    }

    #[test]
    fn train_entries_match_layout_sizes() {
        let Some(m) = manifest() else { return };
        for e in m.entries.values() {
            if e.kind == "train_step" {
                let layout =
                    m.layout(e.layout_key.as_ref().unwrap()).unwrap();
                assert_eq!(e.inputs[0].shape, vec![layout.blob_len]);
                assert_eq!(e.output_shape, vec![layout.blob_len]);
            }
        }
    }

    #[test]
    fn n_params_matches_memsim_arch() {
        let Some(m) = manifest() else { return };
        for (name, p) in &m.presets {
            let arch = crate::memsim::Arch::preset(name).unwrap();
            assert_eq!(arch.n_params(), p.n_params, "{name}");
        }
    }
}
