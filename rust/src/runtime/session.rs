//! PJRT session: HLO loading, compilation cache, typed execution.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::{Entry, Manifest};

/// Host-side input for one entry argument.
pub enum HostArg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    /// Scalar i32 (e.g. the init seed).
    ScalarI32(i32),
}

/// A compiled-artifact session bound to one PJRT (CPU) client.
///
/// Compilation is cached per entry name; `stats()` exposes compile/execute
/// counters for the perf pass.
pub struct Session {
    client: PjRtClient,
    pub manifest: Manifest,
    // BTreeMap (not HashMap) so any future iteration over the cache is
    // deterministic — the `analyze` determinism rule pins this.
    cache: Mutex<BTreeMap<String, PjRtLoadedExecutable>>,
    stats: Mutex<SessionStats>,
}

#[derive(Debug, Default, Clone, Copy)]
pub struct SessionStats {
    pub compiles: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
    pub host_uploads: usize,
    pub upload_bytes: usize,
}

impl Session {
    pub fn open(artifacts_dir: &Path) -> Result<Session> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?;
        Ok(Session {
            client,
            manifest,
            cache: Mutex::new(BTreeMap::new()),
            stats: Mutex::new(SessionStats::default()),
        })
    }

    /// Default artifacts location relative to the repo root.
    pub fn open_default() -> Result<Session> {
        let dir = std::env::var("ADALOMO_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::open(Path::new(&dir))
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn stats(&self) -> SessionStats {
        *self.stats.lock().unwrap()
    }

    /// Compile (or fetch from cache) an entry. Compilation happens lazily
    /// on first execution; call this eagerly to move the cost off the
    /// timed path.
    pub fn compile(&self, entry_name: &str) -> Result<()> {
        {
            let cache = self.cache.lock().unwrap();
            if cache.contains_key(entry_name) {
                return Ok(());
            }
        }
        let entry = self.manifest.entry(entry_name)?;
        let path = self.manifest.hlo_path(entry);
        // ANALYZE-WAIVE(determinism): compile-time stats only, never fed back
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("load {path:?}: {e:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {entry_name}: {e:?}"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut stats = self.stats.lock().unwrap();
            stats.compiles += 1;
            stats.compile_secs += dt;
        }
        self.cache.lock().unwrap().insert(entry_name.to_string(), exe);
        Ok(())
    }

    fn with_exe<R>(
        &self,
        entry_name: &str,
        f: impl FnOnce(&PjRtLoadedExecutable) -> Result<R>,
    ) -> Result<R> {
        self.compile(entry_name)?;
        let cache = self.cache.lock().unwrap();
        // An anyhow error (not expect): a panic here would poison the
        // compile cache for every other session user.
        f(cache
            .get(entry_name)
            .ok_or_else(|| anyhow!("{entry_name} missing from cache"))?)
    }

    fn check_args(&self, entry: &Entry, n: usize) -> Result<()> {
        if entry.inputs.len() != n {
            bail!(
                "{} expects {} inputs, got {n}",
                entry.name,
                entry.inputs.len()
            );
        }
        Ok(())
    }

    /// Upload a host array to the device.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        let mut stats = self.stats.lock().unwrap();
        stats.host_uploads += 1;
        stats.upload_bytes += data.len() * 4;
        drop(stats);
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32: {e:?}"))
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        let mut stats = self.stats.lock().unwrap();
        stats.host_uploads += 1;
        stats.upload_bytes += data.len() * 4;
        drop(stats);
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32: {e:?}"))
    }

    pub fn upload(&self, arg: &HostArg, dims: &[usize]) -> Result<PjRtBuffer> {
        match arg {
            HostArg::F32(d) => self.upload_f32(d, dims),
            HostArg::I32(d) => self.upload_i32(d, dims),
            HostArg::ScalarI32(v) => self.upload_i32(&[*v], &[]),
        }
    }

    /// Execute with device-resident buffers (THE hot path). Returns the
    /// single output buffer, still on device.
    pub fn execute_buf(
        &self,
        entry_name: &str,
        args: &[&PjRtBuffer],
    ) -> Result<PjRtBuffer> {
        let entry = self.manifest.entry(entry_name)?;
        self.check_args(entry, args.len())?;
        // ANALYZE-WAIVE(determinism): execute-time stats only, never fed back
        let t0 = Instant::now();
        let mut out = self.with_exe(entry_name, |exe| {
            exe.execute_b(args).map_err(|e| anyhow!("{entry_name}: {e:?}"))
        })?;
        let result = take_single(&mut out, entry_name)?;
        let mut stats = self.stats.lock().unwrap();
        stats.executions += 1;
        stats.execute_secs += t0.elapsed().as_secs_f64();
        Ok(result)
    }

    /// Execute from host data (convenience path for init/eval/one-shots).
    pub fn execute_host(
        &self,
        entry_name: &str,
        args: &[HostArg],
    ) -> Result<PjRtBuffer> {
        let entry = self.manifest.entry(entry_name)?;
        self.check_args(entry, args.len())?;
        let shapes: Vec<Vec<usize>> =
            entry.inputs.iter().map(|i| i.shape.clone()).collect();
        let bufs: Vec<PjRtBuffer> = args
            .iter()
            .zip(&shapes)
            .map(|(a, dims)| self.upload(a, dims))
            .collect::<Result<_>>()?;
        let refs: Vec<&PjRtBuffer> = bufs.iter().collect();
        self.execute_buf(entry_name, &refs)
    }

    /// Fetch a device buffer to a host f32 vector.
    pub fn fetch_f32(&self, buf: &PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Fetch exactly `n` leading f32 elements. (TFRT CPU PJRT does not
    /// implement CopyRawToHost, so this goes through a Literal; for the
    /// 8-float metrics reads the cost is dominated by the sync anyway.)
    pub fn fetch_f32_raw(&self, buf: &PjRtBuffer, n: usize) -> Result<Vec<f32>> {
        let mut out = self.fetch_f32(buf)?;
        if out.len() < n {
            bail!("buffer holds {} f32s, wanted {n}", out.len());
        }
        out.truncate(n);
        Ok(out)
    }

    /// Literal-level escape hatch (used by tests comparing against
    /// hand-built literals).
    pub fn execute_literals(
        &self,
        entry_name: &str,
        args: &[Literal],
    ) -> Result<Literal> {
        let entry = self.manifest.entry(entry_name)?;
        self.check_args(entry, args.len())?;
        let mut out = self.with_exe(entry_name, |exe| {
            exe.execute::<Literal>(args)
                .map_err(|e| anyhow!("{entry_name}: {e:?}"))
        })?;
        let buf = take_single(&mut out, entry_name)?;
        buf.to_literal_sync().map_err(|e| anyhow!("{e:?}"))
    }

    /// Names of all manifest entries for a preset (used by the smoke test
    /// that compiles everything).
    pub fn entries_for_preset(&self, preset: &str) -> Vec<String> {
        self.manifest
            .entries
            .values()
            .filter(|e| e.preset.as_deref() == Some(preset))
            .map(|e| e.name.clone())
            .collect()
    }
}

fn take_single(
    out: &mut Vec<Vec<PjRtBuffer>>,
    entry_name: &str,
) -> Result<PjRtBuffer> {
    let replica = out
        .get_mut(0)
        .ok_or_else(|| anyhow!("{entry_name}: no replica output"))?;
    if replica.len() != 1 {
        bail!(
            "{entry_name}: expected 1 output buffer, got {} — every AOT \
             entry must return a single array (see aot.py)",
            replica.len()
        );
    }
    Ok(replica.remove(0))
}

impl std::fmt::Debug for Session {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Session")
            .field("platform", &self.client.platform_name())
            .field("entries", &self.manifest.entries.len())
            .finish()
    }
}
