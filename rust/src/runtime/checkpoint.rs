//! Versioned binary checkpoints for the unified execution engine.
//!
//! A checkpoint freezes everything the engine needs to continue a run
//! bitwise-identically after a process restart: the [`Layout`] (so the
//! resumed process can rebuild the flat optimizer without a manifest),
//! the full state blob (parameters + optimizer state + metrics), the
//! completed-step counter, and a [`PlanRecord`] — the serialized form of
//! `coordinator::engine::ExecPlan` plus the position inside it. The
//! format is self-contained and little-endian throughout; the leading
//! `ADCP` magic + version word make incompatible readers fail loudly
//! instead of misparsing.
//!
//! The read path parses **untrusted bytes** and must never panic: every
//! length is bounds-checked before use, the fuzz test
//! `mutated_headers_never_panic` pins it, and the `analyze`
//! panic-discipline rule budgets this file at zero `unwrap()`/`expect()`
//! in non-test code (docs/ANALYSIS.md). Keep new read-path errors on the
//! `anyhow` path.
//!
//! This module sits BELOW the coordinator layer, so it cannot name
//! `ExecPlan` directly: [`PlanRecord`] is the plain-data mirror the
//! coordinator converts to and from. The small float codecs here
//! ([`write_f32s`]/[`read_f32s`], [`write_u16s`]/[`read_u16s`]) are shared
//! with [`super::HostBlob`]'s simpler params-only checkpoint so the file
//! formats cannot drift in how they spell an element.
//!
//! # Versions
//!
//! * **v1** — all-f32: segments carry no dtype tag and the blob is a flat
//!   f32 array. Still readable: a v1 file loads as an all-[`Dtype::F32`]
//!   checkpoint, bit-exactly.
//! * **v2** — dtype-aware: every segment record carries a storage-dtype
//!   tag, the plan records its dtype axis, and the blob body stores the
//!   shardable prefix at the storage dtype (raw bf16 bit patterns for
//!   bf16 layouts) with the metrics tail always f32. A bf16 checkpoint
//!   is therefore ~half the bytes of its f32 twin — measured and gated
//!   by `checkpoint_file_bytes_bf16` in the bench baseline. Still
//!   readable: the wire rung defaults to the plan's storage dtype and
//!   the error-feedback section to empty, bit-exactly what a pre-ladder
//!   run would resume as.
//! * **v3** — wire-ladder-aware: the plan records its exchange
//!   wire rung (`WIRE_*` byte after the plan dtype byte), and a per-rank
//!   error-feedback section (count + length-prefixed f32 arrays) sits
//!   between the plan cursors and the blob so quantized (q8) exchanges
//!   resume with their exact unsent residuals (docs/EXCHANGE.md). For
//!   f32/bf16 wires the section is an empty count and the file is 5
//!   bytes longer than its v2 twin.
//! * **v4** (current) — membership-epoch-aware (docs/FAULTS.md): the plan
//!   record gains an epoch schedule (count + `(start_step u64,
//!   n_ranks u32)` entries, directly after the cursors) describing rank
//!   join/leave points, so an elastic run resumes under the same
//!   membership it would have had uninterrupted. Pre-v4 files load with
//!   an empty schedule (fixed membership — their only possible
//!   behavior); a fixed-membership v4 file is 4 bytes (one empty count)
//!   longer than its v3 twin.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::tensor::Dtype;

use super::blob::TypedBlob;
use super::manifest::{Layout, Segment};

/// File magic for engine checkpoints ("ADalomo CheckPoint").
pub const MAGIC: &[u8; 4] = b"ADCP";

/// Current format version. Readers accept [`V1`]..=this; the version is
/// bumped whenever a field is added or re-encoded.
pub const VERSION: u32 = 4;

/// The all-f32 legacy format (no dtype tags, flat f32 blob body).
pub const V1: u32 = 1;

/// The dtype-aware, pre-wire-ladder format (no wire byte, no
/// error-feedback section).
pub const V2: u32 = 2;

/// The wire-ladder format (no membership-epoch schedule).
pub const V3: u32 = 3;

/// Plain-data mirror of the coordinator's `ExecPlan`, plus the position
/// inside it. Enum axes are stored as u8 codes (see the `PROD_*`/`ORD_*`/
/// `GRAN_*`/`MODE_*` constants); the optimizer is stored by name so new
/// kinds never renumber old files.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanRecord {
    /// Gradient production: [`PROD_FULL_IMAGE`] | [`PROD_GROUPED`].
    pub production: u8,
    /// Exchange order: [`ORD_ASCENDING`] | [`ORD_DESCENDING`].
    pub order: u8,
    /// Step granularity: [`GRAN_WHOLE_IMAGE`] | [`GRAN_TASKS`] |
    /// [`GRAN_GROUPS`].
    pub granularity: u8,
    /// Shard plan: [`MODE_SEGMENTS`] | [`MODE_CONTIGUOUS`].
    pub mode: u8,
    /// Storage dtype axis: [`DT_F32`] | [`DT_BF16`] (v1 files load as
    /// [`DT_F32`]).
    pub dtype: u8,
    /// Exchange wire rung: [`WIRE_F32`] | [`WIRE_BF16`] | [`WIRE_Q8`].
    /// Pre-v3 files load with the wire following the plan dtype (their
    /// only possible behavior) — the `WIRE_*` codes deliberately equal
    /// the `DT_*` codes so that default is a plain byte copy.
    pub wire: u8,
    /// Optimizer name (`OptKind::name()` spelling).
    pub opt: String,
    /// Total steps the plan runs for.
    pub steps: u64,
    /// Exchange bucket size in f32 elements (tasks granularity).
    pub bucket_elems: u64,
    pub n_ranks: u32,
    pub n_shards: u32,
    pub lr: f32,
    pub wd: f32,
    /// Fabric model: per-hop latency (s) and per-link bandwidth (B/s).
    pub fabric_alpha: f64,
    pub fabric_bw: f64,
    /// Source seed for deterministic host-mirror gradient streams — what
    /// lets a resumed CLI run reconstruct identical rank sources.
    pub seed: u64,
    /// Position inside the interrupted step: fused-group and fused-order
    /// task cursors. Version-1 writers only checkpoint at step
    /// boundaries, so both are always zero — readers validate that
    /// rather than silently resuming mid-step.
    pub cursor_group: u64,
    pub cursor_task: u64,
    /// Membership-epoch schedule (v4, docs/FAULTS.md): each `(s, r)`
    /// entry means "after completed step `s`, membership becomes `r`
    /// ranks" — steps `s+1..` run with `r` ranks until the next entry.
    /// [`PlanRecord::n_ranks`] stays the epoch-0 count. Entries are
    /// strictly increasing in `s` with `1 <= s < steps` and `r >= 1`;
    /// empty means fixed membership (every pre-v4 file).
    pub epochs: Vec<(u64, u32)>,
}

impl PlanRecord {
    /// Rank count in effect while executing step `t` (1-based): the `r`
    /// of the last epoch entry with `s < t`, or [`Self::n_ranks`] before
    /// any boundary has passed.
    pub fn ranks_at(&self, t: u64) -> u32 {
        let mut ranks = self.n_ranks;
        for &(s, r) in &self.epochs {
            if s < t {
                ranks = r;
            } else {
                break;
            }
        }
        ranks
    }

    /// Rank count governing the NEXT step after `done` completed steps —
    /// what a resumed engine (and its error-feedback state) must be
    /// sized for. Entries pin `s < steps`, so this is also well-defined
    /// for a finished run.
    pub fn current_ranks(&self, done: u64) -> u32 {
        self.ranks_at(done.saturating_add(1))
    }
}

pub const PROD_FULL_IMAGE: u8 = 0;
pub const PROD_GROUPED: u8 = 1;
pub const ORD_ASCENDING: u8 = 0;
pub const ORD_DESCENDING: u8 = 1;
pub const GRAN_WHOLE_IMAGE: u8 = 0;
pub const GRAN_TASKS: u8 = 1;
pub const GRAN_GROUPS: u8 = 2;
pub const MODE_SEGMENTS: u8 = 0;
pub const MODE_CONTIGUOUS: u8 = 1;
pub const DT_F32: u8 = 0;
pub const DT_BF16: u8 = 1;
/// Wire-rung codes (v3). [`WIRE_F32`]/[`WIRE_BF16`] intentionally match
/// [`DT_F32`]/[`DT_BF16`] so pre-v3 readers' wire-follows-dtype default
/// is a byte copy of the plan dtype code.
pub const WIRE_F32: u8 = 0;
pub const WIRE_BF16: u8 = 1;
pub const WIRE_Q8: u8 = 2;

/// [`Dtype`] -> on-disk code.
pub fn dtype_code(d: Dtype) -> u8 {
    match d {
        Dtype::F32 => DT_F32,
        Dtype::Bf16 => DT_BF16,
    }
}

/// On-disk code -> [`Dtype`], rejecting unknown codes loudly.
pub fn dtype_from_code(c: u8) -> Result<Dtype> {
    match c {
        DT_F32 => Ok(Dtype::F32),
        DT_BF16 => Ok(Dtype::Bf16),
        other => bail!("unknown dtype code {other}"),
    }
}

/// Everything a checkpoint file holds.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Layout key the blob was trained under (`preset/opt` spelling).
    pub layout_key: String,
    pub layout: Layout,
    /// Completed optimizer steps at save time.
    pub step: u64,
    pub plan: PlanRecord,
    /// Per-rank error-feedback accumulators (v3): one `params_len`-long
    /// f32 array per rank when the plan's wire rung is [`WIRE_Q8`], empty
    /// otherwise (and always empty in pre-v3 files). The coordinator
    /// re-injects these residuals into each rank's next quantized
    /// payload, so they must resume bit-exactly.
    pub ef: Vec<Vec<f32>>,
    /// Full blob in its STORAGE dtype: parameter, optimizer-state and
    /// metrics regions (bf16 prefixes round-trip bit-exactly — no widen/
    /// re-round on the save/load path).
    pub blob: TypedBlob,
}

// --- little-endian writers/readers -------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Append `data` as raw little-endian f32s (4 bytes each, no length
/// prefix — callers write their own counts).
pub fn write_f32s(out: &mut Vec<u8>, data: &[f32]) {
    out.reserve(data.len() * 4);
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Decode exactly `n` little-endian f32s; `bytes` must hold exactly
/// `4 * n` bytes (a trailing-garbage or truncated body is an error, not a
/// partial read). The byte count is computed with checked arithmetic and
/// compared BEFORE any allocation, so a corrupt length can neither wrap
/// the comparison nor trigger a huge `Vec` reservation.
pub fn read_f32s(bytes: &[u8], n: usize) -> Result<Vec<f32>> {
    ensure!(
        n.checked_mul(4) == Some(bytes.len()),
        "f32 body holds {} bytes, expected 4 x {n}",
        bytes.len()
    );
    let mut data = Vec::with_capacity(n);
    for chunk in bytes.chunks_exact(4) {
        data.push(f32::from_le_bytes(chunk.try_into()?));
    }
    Ok(data)
}

/// Append `data` as raw little-endian u16s — the bf16-bit-pattern half of
/// the blob codec ([`write_f32s`]'s 2-byte sibling).
pub fn write_u16s(out: &mut Vec<u8>, data: &[u16]) {
    out.reserve(data.len() * 2);
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Decode exactly `n` little-endian u16s, with the same
/// checked-before-allocating strictness as [`read_f32s`].
pub fn read_u16s(bytes: &[u8], n: usize) -> Result<Vec<u16>> {
    ensure!(
        n.checked_mul(2) == Some(bytes.len()),
        "u16 body holds {} bytes, expected 2 x {n}",
        bytes.len()
    );
    let mut data = Vec::with_capacity(n);
    for chunk in bytes.chunks_exact(2) {
        data.push(u16::from_le_bytes(chunk.try_into()?));
    }
    Ok(data)
}

/// Bounds-checked cursor over a checkpoint body.
struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.pos + n <= self.bytes.len(),
            "truncated checkpoint (need {} bytes at offset {}, have {})",
            n,
            self.pos,
            self.bytes.len()
        );
        let piece = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(piece)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into()?))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into()?))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into()?))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into()?))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }

    fn usize64(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }

    /// Read a u32 element count, bounded against the remaining input:
    /// each counted element occupies at least `min_elem_bytes` of the
    /// bytes still unread, so a corrupt header cannot demand a huge
    /// allocation before the body parse fails.
    fn count32(&mut self, min_elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        let remaining = self.bytes.len() - self.pos;
        ensure!(
            n.checked_mul(min_elem_bytes).is_some_and(|b| b <= remaining),
            "corrupt checkpoint: count {n} (x{min_elem_bytes}B) exceeds \
             the {remaining} remaining bytes"
        );
        Ok(n)
    }

    /// Read a u64 element length with the same remaining-bytes bound as
    /// [`Self::count32`] — the guarded form of the old unchecked
    /// `u64 as usize` reads.
    fn len64(&mut self, min_elem_bytes: usize) -> Result<usize> {
        let n = self.u64()?;
        let remaining = (self.bytes.len() - self.pos) as u64;
        ensure!(
            min_elem_bytes > 0 && n <= remaining / min_elem_bytes as u64,
            "corrupt checkpoint: length {n} (x{min_elem_bytes}B) exceeds \
             the {remaining} remaining bytes"
        );
        Ok(n as usize)
    }
}

/// Serialize `ck` into the current (version-4) byte layout.
pub fn to_bytes(ck: &Checkpoint) -> Vec<u8> {
    encode(&ck.layout_key, &ck.layout, ck.step, &ck.plan, &ck.ef, &ck.blob)
}

/// The version-4 encoder over borrowed parts — what [`write`] uses so
/// the engine can checkpoint without cloning its blob first. The blob
/// body is the typed storage verbatim: bf16 prefix bits then the f32
/// tail (for f32 storage the prefix is empty and the tail is the whole
/// blob — one spelling covers both dtypes).
fn encode(
    layout_key: &str,
    layout: &Layout,
    step: u64,
    plan: &PlanRecord,
    ef: &[Vec<f32>],
    blob: &TypedBlob,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + blob.storage_bytes());
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    put_str(&mut out, layout_key);
    // Layout.
    put_u64(&mut out, layout.blob_len as u64);
    put_u64(&mut out, layout.params_len as u64);
    put_u32(&mut out, layout.segments.len() as u32);
    for s in &layout.segments {
        put_str(&mut out, &s.name);
        put_str(&mut out, &s.kind);
        put_u32(&mut out, s.shape.len() as u32);
        for &d in &s.shape {
            put_u64(&mut out, d as u64);
        }
        put_u64(&mut out, s.offset as u64);
        put_u64(&mut out, s.size as u64);
        // v2: per-region storage-dtype tag.
        out.push(dtype_code(s.dtype));
    }
    put_u64(&mut out, step);
    // Plan record.
    out.push(plan.production);
    out.push(plan.order);
    out.push(plan.granularity);
    out.push(plan.mode);
    // v2: the plan's storage-dtype axis.
    out.push(plan.dtype);
    // v3: the plan's exchange wire rung.
    out.push(plan.wire);
    put_str(&mut out, &plan.opt);
    put_u64(&mut out, plan.steps);
    put_u64(&mut out, plan.bucket_elems);
    put_u32(&mut out, plan.n_ranks);
    put_u32(&mut out, plan.n_shards);
    put_f32(&mut out, plan.lr);
    put_f32(&mut out, plan.wd);
    put_f64(&mut out, plan.fabric_alpha);
    put_f64(&mut out, plan.fabric_bw);
    put_u64(&mut out, plan.seed);
    put_u64(&mut out, plan.cursor_group);
    put_u64(&mut out, plan.cursor_task);
    // v4: membership-epoch schedule (empty count for fixed membership).
    put_u32(&mut out, plan.epochs.len() as u32);
    for &(s, ranks) in &plan.epochs {
        put_u64(&mut out, s);
        put_u32(&mut out, ranks);
    }
    // v3: per-rank error-feedback section (empty count for exact wires),
    // kept BEFORE the blob so the blob body stays the strict file tail.
    put_u32(&mut out, ef.len() as u32);
    for e in ef {
        put_u64(&mut out, e.len() as u64);
        write_f32s(&mut out, e);
    }
    // Blob: element count, then the raw typed storage.
    put_u64(&mut out, blob.len() as u64);
    write_u16s(&mut out, blob.prefix_bits());
    write_f32s(&mut out, blob.f32_part());
    out
}

/// Encode `ck` in the LEGACY v1 byte layout — all-f32 checkpoints only
/// (v1 has no dtype tags). The single authoritative spelling of the
/// legacy format: the compatibility tests (here and in
/// `integration_engine.rs`) write their PR-4-era files through this, and
/// the unit test additionally pins its output against an independent
/// hand-rolled byte stream so the two readers/writers cannot drift.
pub fn to_bytes_v1(ck: &Checkpoint) -> Result<Vec<u8>> {
    ensure!(
        ck.blob.dtype() == Dtype::F32
            && ck.layout.storage_dtype()? == Dtype::F32
            && ck.plan.dtype == DT_F32,
        "the v1 format is all-f32; widen/retag the checkpoint first"
    );
    ensure!(
        ck.plan.wire == WIRE_F32 && ck.ef.is_empty(),
        "the v1 format predates the wire ladder; it can only spell the \
         f32 wire with no error-feedback state"
    );
    ensure!(
        ck.plan.epochs.is_empty(),
        "the v1 format predates membership epochs; it can only spell \
         fixed-membership plans"
    );
    let mut out = Vec::with_capacity(64 + ck.blob.storage_bytes());
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, V1);
    put_str(&mut out, &ck.layout_key);
    put_u64(&mut out, ck.layout.blob_len as u64);
    put_u64(&mut out, ck.layout.params_len as u64);
    put_u32(&mut out, ck.layout.segments.len() as u32);
    for s in &ck.layout.segments {
        put_str(&mut out, &s.name);
        put_str(&mut out, &s.kind);
        put_u32(&mut out, s.shape.len() as u32);
        for &d in &s.shape {
            put_u64(&mut out, d as u64);
        }
        put_u64(&mut out, s.offset as u64);
        put_u64(&mut out, s.size as u64);
        // v1: no per-segment dtype tag.
    }
    put_u64(&mut out, ck.step);
    out.push(ck.plan.production);
    out.push(ck.plan.order);
    out.push(ck.plan.granularity);
    out.push(ck.plan.mode);
    // v1: no plan dtype byte.
    put_str(&mut out, &ck.plan.opt);
    put_u64(&mut out, ck.plan.steps);
    put_u64(&mut out, ck.plan.bucket_elems);
    put_u32(&mut out, ck.plan.n_ranks);
    put_u32(&mut out, ck.plan.n_shards);
    put_f32(&mut out, ck.plan.lr);
    put_f32(&mut out, ck.plan.wd);
    put_f64(&mut out, ck.plan.fabric_alpha);
    put_f64(&mut out, ck.plan.fabric_bw);
    put_u64(&mut out, ck.plan.seed);
    put_u64(&mut out, ck.plan.cursor_group);
    put_u64(&mut out, ck.plan.cursor_task);
    put_u64(&mut out, ck.blob.len() as u64);
    write_f32s(&mut out, ck.blob.f32_part());
    Ok(out)
}

/// Encode `ck` in the LEGACY v2 byte layout — dtype-aware but
/// pre-wire-ladder, so it can only spell wire-follows-storage plans with
/// no error-feedback state. Like [`to_bytes_v1`], this is the single
/// authoritative spelling of the legacy format: the compatibility tests
/// write their PR-5-era fixture files through it (and pin its output
/// against an independent hand-rolled byte stream).
pub fn to_bytes_v2(ck: &Checkpoint) -> Result<Vec<u8>> {
    ensure!(
        ck.plan.wire == ck.plan.dtype && ck.ef.is_empty(),
        "the v2 format predates the wire ladder; it can only spell \
         wire-follows-storage checkpoints with no error-feedback state"
    );
    ensure!(
        ck.plan.epochs.is_empty(),
        "the v2 format predates membership epochs; it can only spell \
         fixed-membership plans"
    );
    let mut out = Vec::with_capacity(64 + ck.blob.storage_bytes());
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, V2);
    put_str(&mut out, &ck.layout_key);
    put_u64(&mut out, ck.layout.blob_len as u64);
    put_u64(&mut out, ck.layout.params_len as u64);
    put_u32(&mut out, ck.layout.segments.len() as u32);
    for s in &ck.layout.segments {
        put_str(&mut out, &s.name);
        put_str(&mut out, &s.kind);
        put_u32(&mut out, s.shape.len() as u32);
        for &d in &s.shape {
            put_u64(&mut out, d as u64);
        }
        put_u64(&mut out, s.offset as u64);
        put_u64(&mut out, s.size as u64);
        out.push(dtype_code(s.dtype));
    }
    put_u64(&mut out, ck.step);
    out.push(ck.plan.production);
    out.push(ck.plan.order);
    out.push(ck.plan.granularity);
    out.push(ck.plan.mode);
    out.push(ck.plan.dtype);
    // v2: NO wire byte.
    put_str(&mut out, &ck.plan.opt);
    put_u64(&mut out, ck.plan.steps);
    put_u64(&mut out, ck.plan.bucket_elems);
    put_u32(&mut out, ck.plan.n_ranks);
    put_u32(&mut out, ck.plan.n_shards);
    put_f32(&mut out, ck.plan.lr);
    put_f32(&mut out, ck.plan.wd);
    put_f64(&mut out, ck.plan.fabric_alpha);
    put_f64(&mut out, ck.plan.fabric_bw);
    put_u64(&mut out, ck.plan.seed);
    put_u64(&mut out, ck.plan.cursor_group);
    put_u64(&mut out, ck.plan.cursor_task);
    // v2: NO error-feedback section.
    put_u64(&mut out, ck.blob.len() as u64);
    write_u16s(&mut out, ck.blob.prefix_bits());
    write_f32s(&mut out, ck.blob.f32_part());
    Ok(out)
}

/// Encode `ck` in the LEGACY v3 byte layout — wire-ladder-aware but
/// pre-elastic, so it can only spell fixed-membership plans. Like its v1
/// and v2 siblings, this is the single authoritative spelling of the
/// legacy format, pinned against an independent hand-rolled byte stream
/// in the unit tests.
pub fn to_bytes_v3(ck: &Checkpoint) -> Result<Vec<u8>> {
    ensure!(
        ck.plan.epochs.is_empty(),
        "the v3 format predates membership epochs; it can only spell \
         fixed-membership plans"
    );
    let mut out = Vec::with_capacity(64 + ck.blob.storage_bytes());
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, V3);
    put_str(&mut out, &ck.layout_key);
    put_u64(&mut out, ck.layout.blob_len as u64);
    put_u64(&mut out, ck.layout.params_len as u64);
    put_u32(&mut out, ck.layout.segments.len() as u32);
    for s in &ck.layout.segments {
        put_str(&mut out, &s.name);
        put_str(&mut out, &s.kind);
        put_u32(&mut out, s.shape.len() as u32);
        for &d in &s.shape {
            put_u64(&mut out, d as u64);
        }
        put_u64(&mut out, s.offset as u64);
        put_u64(&mut out, s.size as u64);
        out.push(dtype_code(s.dtype));
    }
    put_u64(&mut out, ck.step);
    out.push(ck.plan.production);
    out.push(ck.plan.order);
    out.push(ck.plan.granularity);
    out.push(ck.plan.mode);
    out.push(ck.plan.dtype);
    out.push(ck.plan.wire);
    put_str(&mut out, &ck.plan.opt);
    put_u64(&mut out, ck.plan.steps);
    put_u64(&mut out, ck.plan.bucket_elems);
    put_u32(&mut out, ck.plan.n_ranks);
    put_u32(&mut out, ck.plan.n_shards);
    put_f32(&mut out, ck.plan.lr);
    put_f32(&mut out, ck.plan.wd);
    put_f64(&mut out, ck.plan.fabric_alpha);
    put_f64(&mut out, ck.plan.fabric_bw);
    put_u64(&mut out, ck.plan.seed);
    put_u64(&mut out, ck.plan.cursor_group);
    put_u64(&mut out, ck.plan.cursor_task);
    // v3: NO membership-epoch section.
    put_u32(&mut out, ck.ef.len() as u32);
    for e in &ck.ef {
        put_u64(&mut out, e.len() as u64);
        write_f32s(&mut out, e);
    }
    put_u64(&mut out, ck.blob.len() as u64);
    write_u16s(&mut out, ck.blob.prefix_bits());
    write_f32s(&mut out, ck.blob.f32_part());
    Ok(out)
}

/// Parse a version-1 through -4 checkpoint, validating magic, version,
/// internal layout consistency and exact body length. v1 files load as
/// all-f32 ([`DT_F32`] everywhere, flat f32 blob); pre-v3 files load
/// with the wire rung equal to the plan dtype and no error-feedback
/// state; pre-v4 files load with an empty (fixed-membership) epoch
/// schedule.
pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
    ensure!(
        bytes.len() >= 8 && &bytes[..4] == MAGIC,
        "not an adalomo engine checkpoint (bad magic)"
    );
    let mut r = Reader { bytes, pos: 4 };
    let version = r.u32()?;
    ensure!(
        (V1..=VERSION).contains(&version),
        "checkpoint version {version} unsupported (this build reads \
         {V1}..={VERSION})"
    );
    let layout_key = r.str()?;
    // blob_len is bounded against the remaining bytes: every element
    // occupies at least 2 bytes (bf16) in the body that must follow.
    let blob_len = r.len64(2)?;
    let params_len = r.usize64()?;
    // Each segment record occupies at least 28 bytes (name len + kind len
    // + ndim + offset + size), so the count is bounded before the
    // allocation it sizes.
    let n_segments = r.count32(28)?;
    let mut segments = Vec::with_capacity(n_segments);
    for _ in 0..n_segments {
        let name = r.str()?;
        let kind = r.str()?;
        let ndim = r.count32(8)?;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.usize64()?);
        }
        let offset = r.usize64()?;
        let size = r.usize64()?;
        let dtype = if version >= 2 {
            dtype_from_code(r.u8()?)?
        } else {
            Dtype::F32
        };
        segments.push(Segment { name, kind, shape, offset, size, dtype });
    }
    let layout = Layout { blob_len, params_len, segments };
    validate_layout(&layout)?;
    let step = r.u64()?;
    let production = r.u8()?;
    let order = r.u8()?;
    let granularity = r.u8()?;
    let mode = r.u8()?;
    let plan_dtype = if version >= 2 { r.u8()? } else { DT_F32 };
    // Pre-v3 exchanges could only ship at the storage dtype, and the
    // WIRE_* codes equal the DT_* codes — the default is a byte copy.
    let wire = if version >= 3 { r.u8()? } else { plan_dtype };
    ensure!(
        matches!(wire, WIRE_F32 | WIRE_BF16 | WIRE_Q8),
        "unknown wire-codec code {wire}"
    );
    let mut plan = PlanRecord {
        production,
        order,
        granularity,
        mode,
        dtype: plan_dtype,
        wire,
        opt: r.str()?,
        steps: r.u64()?,
        bucket_elems: r.u64()?,
        n_ranks: r.u32()?,
        n_shards: r.u32()?,
        lr: r.f32()?,
        wd: r.f32()?,
        fabric_alpha: r.f64()?,
        fabric_bw: r.f64()?,
        seed: r.u64()?,
        cursor_group: r.u64()?,
        cursor_task: r.u64()?,
        epochs: Vec::new(),
    };
    // v4: membership-epoch schedule. Each counted entry occupies 12
    // bytes, so the count is bounded before the allocation it sizes.
    if version >= 4 {
        let n_epochs = r.count32(12)?;
        let mut epochs = Vec::with_capacity(n_epochs);
        for _ in 0..n_epochs {
            let s = r.u64()?;
            let ranks = r.u32()?;
            epochs.push((s, ranks));
        }
        plan.epochs = epochs;
    }
    validate_epochs(&plan)?;
    ensure!(
        plan.cursor_group == 0 && plan.cursor_task == 0,
        "mid-step checkpoint (group cursor {}, task cursor {}): readers \
         only resume at step boundaries",
        plan.cursor_group,
        plan.cursor_task
    );
    let dtype = layout.storage_dtype()?;
    ensure!(
        plan.dtype == dtype_code(dtype),
        "plan dtype code {} disagrees with the layout's {} storage",
        plan.dtype,
        dtype.name()
    );
    // v3: per-rank error-feedback section. Each counted entry occupies
    // at least its 8-byte length word, so the count is bounded before
    // the allocation it sizes (same discipline as the segment count).
    let ef = if version >= 3 {
        let n_ranks = r.count32(8)?;
        let mut ef = Vec::with_capacity(n_ranks);
        for _ in 0..n_ranks {
            let len = r.len64(4)?;
            let body = r.take(len * 4)?;
            ef.push(read_f32s(body, len)?);
        }
        ef
    } else {
        Vec::new()
    };
    ensure!(
        plan.wire == WIRE_Q8 || ef.is_empty(),
        "checkpoint carries error-feedback state, but wire code {} keeps \
         none",
        plan.wire
    );
    // EF accumulators belong to the ranks that will run the NEXT step —
    // under an epoch schedule that is the current epoch's count, not
    // necessarily the plan's epoch-0 `n_ranks`.
    ensure!(
        ef.is_empty() || ef.len() == plan.current_ranks(step) as usize,
        "error-feedback section holds {} ranks, the plan's membership at \
         step {} is {}",
        ef.len(),
        step.saturating_add(1),
        plan.current_ranks(step)
    );
    for (rank, e) in ef.iter().enumerate() {
        ensure!(
            e.len() == layout.params_len,
            "rank {rank} error-feedback length {} != params_len {}",
            e.len(),
            layout.params_len
        );
    }
    let n = r.len64(dtype.bytes().min(4))?;
    ensure!(
        n == layout.blob_len,
        "checkpoint blob holds {n} elements, layout says {}",
        layout.blob_len
    );
    let blob = match dtype {
        Dtype::F32 => TypedBlob::from_parts(
            dtype,
            layout.shardable_len(),
            Vec::new(),
            read_f32s(&bytes[r.pos..], n)?,
        )?,
        Dtype::Bf16 => {
            let split = layout.shardable_len();
            let prefix_bytes = split
                .checked_mul(2)
                .filter(|&b| r.pos.checked_add(b).is_some_and(|e| e <= bytes.len()))
                .with_context(|| {
                    format!("truncated checkpoint: bf16 prefix of {split} elems")
                })?;
            let bits = read_u16s(&bytes[r.pos..r.pos + prefix_bytes], split)?;
            let tail = read_f32s(&bytes[r.pos + prefix_bytes..], n - split)?;
            TypedBlob::from_parts(dtype, split, bits, tail)?
        }
    };
    Ok(Checkpoint { layout_key, layout, step, plan, ef, blob })
}

/// Epoch-schedule invariants, shared by [`from_bytes`] and [`write`]:
/// boundaries strictly increasing and strictly inside the run
/// (`1 <= s < steps` — a boundary at step 0 or past the end describes a
/// membership change that can never happen), every epoch at least one
/// rank. The checked arithmetic-free walk cannot panic on crafted input.
fn validate_epochs(plan: &PlanRecord) -> Result<()> {
    let mut prev = 0u64;
    for &(s, ranks) in &plan.epochs {
        ensure!(ranks >= 1, "membership epoch at step {s} declares 0 ranks");
        ensure!(
            s >= 1 && s < plan.steps,
            "membership epoch boundary {s} outside the run (1..{} valid)",
            plan.steps
        );
        ensure!(
            s > prev,
            "membership epoch boundaries must be strictly increasing \
             ({s} follows {prev})"
        );
        prev = s;
    }
    Ok(())
}

/// The serialized layout must be internally consistent before anything
/// trusts its offsets: segments tile `[0, blob_len)` exactly and the
/// parameter region is a prefix. All arithmetic on the untrusted sizes is
/// checked, so crafted dimensions error instead of overflowing.
fn validate_layout(layout: &Layout) -> Result<()> {
    let mut off = 0usize;
    for s in &layout.segments {
        ensure!(
            s.offset == off,
            "checkpoint layout: segment {} at offset {} (expected {off})",
            s.name,
            s.offset
        );
        let shape_elems = s
            .shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .with_context(|| {
                format!("checkpoint layout: segment {} shape overflows", s.name)
            })?;
        ensure!(
            s.size == shape_elems.max(1),
            "checkpoint layout: segment {} size {} != shape {:?}",
            s.name,
            s.size,
            s.shape
        );
        off = off.checked_add(s.size).with_context(|| {
            format!("checkpoint layout: offsets overflow at {}", s.name)
        })?;
    }
    ensure!(
        off == layout.blob_len,
        "checkpoint layout: segments cover {off} of {} elements",
        layout.blob_len
    );
    ensure!(
        layout.params_len <= layout.blob_len,
        "checkpoint layout: params_len {} > blob_len {}",
        layout.params_len,
        layout.blob_len
    );
    ensure!(
        layout.params_len <= layout.shardable_len(),
        "checkpoint layout: params_len {} reaches into the metrics region",
        layout.params_len
    );
    Ok(())
}

/// Write `ck` to `path` crash-safely (see [`write`]).
pub fn save(path: &Path, ck: &Checkpoint) -> Result<()> {
    write(
        path,
        &ck.layout_key,
        &ck.layout,
        ck.step,
        &ck.plan,
        &ck.ef,
        &ck.blob,
    )
}

/// [`save`] over borrowed parts: validates and serializes without the
/// caller assembling an owned [`Checkpoint`] first — the engine's
/// checkpoint path uses this so the state blob (its largest object) is
/// never cloned just to be written out.
///
/// The write is crash-safe: bytes go to a same-directory temp name and
/// are renamed over `path` only once fully written, so a kill mid-save
/// can never leave a torn file at the final path (nor destroy the
/// previous checkpoint there) — that torn file would otherwise defeat
/// the restart-survival guarantee checkpoints exist for.
pub fn write(
    path: &Path,
    layout_key: &str,
    layout: &Layout,
    step: u64,
    plan: &PlanRecord,
    ef: &[Vec<f32>],
    blob: &TypedBlob,
) -> Result<()> {
    ensure!(
        blob.len() == layout.blob_len,
        "checkpoint blob {} elements != layout {}",
        blob.len(),
        layout.blob_len
    );
    let dtype = layout.storage_dtype()?;
    ensure!(
        blob.dtype() == dtype,
        "checkpoint blob stored as {} but the layout is tagged {}",
        blob.dtype().name(),
        dtype.name()
    );
    ensure!(
        blob.dtype() == Dtype::F32 || blob.split() == layout.shardable_len(),
        "checkpoint blob splits at {} but the layout's shardable region \
         ends at {}",
        blob.split(),
        layout.shardable_len()
    );
    ensure!(
        plan.dtype == dtype_code(dtype),
        "plan dtype code {} disagrees with the layout's {} storage",
        plan.dtype,
        dtype.name()
    );
    ensure!(
        plan.wire == WIRE_Q8 || ef.is_empty(),
        "wire code {} keeps no error-feedback state, but {} rank \
         accumulators were passed",
        plan.wire,
        ef.len()
    );
    ensure!(
        ef.is_empty() || ef.len() == plan.current_ranks(step) as usize,
        "error-feedback for {} ranks, the plan's membership at step {} \
         is {}",
        ef.len(),
        step.saturating_add(1),
        plan.current_ranks(step)
    );
    for (rank, e) in ef.iter().enumerate() {
        ensure!(
            e.len() == layout.params_len,
            "rank {rank} error-feedback length {} != params_len {}",
            e.len(),
            layout.params_len
        );
    }
    validate_epochs(plan)?;
    validate_layout(layout)?;
    let tmp = temp_sibling(path);
    std::fs::write(&tmp, encode(layout_key, layout, step, plan, ef, blob))
        .with_context(|| format!("write checkpoint {tmp:?}"))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publish checkpoint {path:?}"))
}

/// Same-directory temp name (rename is only atomic within a filesystem);
/// the pid keeps concurrent writers from clobbering each other's
/// in-flight bytes.
fn temp_sibling(path: &Path) -> std::path::PathBuf {
    let mut name = path
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_else(|| "checkpoint".into());
    name.push(format!(".{}.tmp", std::process::id()));
    path.with_file_name(name)
}

/// Read and validate a checkpoint file.
pub fn load(path: &Path) -> Result<Checkpoint> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("read checkpoint {path:?}"))?;
    if bytes.len() >= 4 && &bytes[..4] != MAGIC {
        bail!(
            "{path:?} is not an engine checkpoint (HostBlob-style files \
             start with ADLM, engine checkpoints with ADCP)"
        );
    }
    from_bytes(&bytes)
        .with_context(|| format!("parse checkpoint {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_layout(dtype: Dtype) -> Layout {
        let segments = vec![
            Segment {
                name: "w".into(),
                kind: "param".into(),
                shape: vec![2, 3],
                offset: 0,
                size: 6,
                dtype,
            },
            Segment {
                name: "w@v".into(),
                kind: "state".into(),
                shape: vec![6],
                offset: 6,
                size: 6,
                dtype,
            },
            Segment {
                name: "metrics".into(),
                kind: "metric".into(),
                shape: vec![8],
                offset: 12,
                size: 8,
                dtype: Dtype::F32,
            },
        ];
        Layout { blob_len: 20, params_len: 6, segments }
    }

    fn sample_with(dtype: Dtype) -> Checkpoint {
        let layout = sample_layout(dtype);
        let image: Vec<f32> = (0..20).map(|i| i as f32 * 0.25 - 1.0).collect();
        let blob = TypedBlob::from_f32(&layout, &image, dtype).unwrap();
        Checkpoint {
            layout_key: "nano/adalomo".into(),
            layout,
            step: 7,
            plan: PlanRecord {
                production: PROD_GROUPED,
                order: ORD_DESCENDING,
                granularity: GRAN_TASKS,
                mode: MODE_CONTIGUOUS,
                dtype: dtype_code(dtype),
                wire: dtype_code(dtype),
                opt: "adalomo".into(),
                steps: 12,
                bucket_elems: 64,
                n_ranks: 2,
                n_shards: 3,
                lr: 1e-2,
                wd: 0.01,
                fabric_alpha: 8e-6,
                fabric_bw: 170e9,
                seed: 42,
                cursor_group: 0,
                cursor_task: 0,
                epochs: Vec::new(),
            },
            ef: Vec::new(),
            blob,
        }
    }

    /// An f32 sample with a two-boundary membership schedule: 2 ranks
    /// for steps 1..=4, then 3 for 5..=9, then 1 for 10..=12.
    fn sample_elastic() -> Checkpoint {
        let mut ck = sample_with(Dtype::F32);
        ck.plan.epochs = vec![(4, 3), (9, 1)];
        ck
    }

    /// An f32 sample retagged to the q8 wire, carrying per-rank
    /// error-feedback accumulators.
    fn sample_q8() -> Checkpoint {
        let mut ck = sample_with(Dtype::F32);
        ck.plan.wire = WIRE_Q8;
        let params = ck.layout.params_len;
        ck.ef = (0..ck.plan.n_ranks as usize)
            .map(|r| (0..params).map(|i| (r * params + i) as f32 * 1e-3).collect())
            .collect();
        ck
    }

    fn sample() -> Checkpoint {
        sample_with(Dtype::F32)
    }

    #[test]
    fn round_trip_is_exact() {
        for dtype in [Dtype::F32, Dtype::Bf16] {
            let ck = sample_with(dtype);
            let bytes = to_bytes(&ck);
            let back = from_bytes(&bytes).unwrap();
            assert_eq!(back, ck);
            // Exact storage bits survive, not just approximate values —
            // for bf16 that means the raw u16 prefix, with no widen/
            // re-round on the save/load path.
            assert_eq!(back.blob.prefix_bits(), ck.blob.prefix_bits());
            for (a, b) in ck.blob.to_f32().iter().zip(&back.blob.to_f32()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            // Serialization is deterministic: same checkpoint, same bytes.
            assert_eq!(bytes, to_bytes(&back));
        }
        // The bf16 file is about half the f32 one (the tentpole's
        // checkpoint-byte claim in miniature).
        let f32_bytes = to_bytes(&sample_with(Dtype::F32)).len();
        let bf16_bytes = to_bytes(&sample_with(Dtype::Bf16)).len();
        // Identical headers (plus tags); blob 12x2+8x4 vs 20x4.
        assert_eq!(f32_bytes - bf16_bytes, 20 * 4 - (12 * 2 + 8 * 4));
    }

    /// The v1 (all-f32, tagless) format still loads — as all-f32, with
    /// every value bit-exact. This is the byte layout PR-4 era files have
    /// on disk, reproduced by hand so the compatibility surface cannot
    /// drift silently.
    #[test]
    fn v1_files_load_as_all_f32() {
        let ck = sample();
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        put_u32(&mut out, V1);
        put_str(&mut out, &ck.layout_key);
        put_u64(&mut out, ck.layout.blob_len as u64);
        put_u64(&mut out, ck.layout.params_len as u64);
        put_u32(&mut out, ck.layout.segments.len() as u32);
        for s in &ck.layout.segments {
            put_str(&mut out, &s.name);
            put_str(&mut out, &s.kind);
            put_u32(&mut out, s.shape.len() as u32);
            for &d in &s.shape {
                put_u64(&mut out, d as u64);
            }
            put_u64(&mut out, s.offset as u64);
            put_u64(&mut out, s.size as u64);
            // v1: NO dtype tag.
        }
        put_u64(&mut out, ck.step);
        out.push(ck.plan.production);
        out.push(ck.plan.order);
        out.push(ck.plan.granularity);
        out.push(ck.plan.mode);
        // v1: NO plan dtype byte.
        put_str(&mut out, &ck.plan.opt);
        put_u64(&mut out, ck.plan.steps);
        put_u64(&mut out, ck.plan.bucket_elems);
        put_u32(&mut out, ck.plan.n_ranks);
        put_u32(&mut out, ck.plan.n_shards);
        put_f32(&mut out, ck.plan.lr);
        put_f32(&mut out, ck.plan.wd);
        put_f64(&mut out, ck.plan.fabric_alpha);
        put_f64(&mut out, ck.plan.fabric_bw);
        put_u64(&mut out, ck.plan.seed);
        put_u64(&mut out, ck.plan.cursor_group);
        put_u64(&mut out, ck.plan.cursor_task);
        put_u64(&mut out, ck.blob.len() as u64);
        write_f32s(&mut out, ck.blob.f32_part());

        // The hand-rolled bytes ARE what the shared v1 encoder emits —
        // the independent pin that keeps `to_bytes_v1` honest.
        assert_eq!(out, to_bytes_v1(&ck).unwrap());
        let back = from_bytes(&out).unwrap();
        assert_eq!(back, ck); // sample() is all-f32 + DT_F32 already
        assert_eq!(back.layout.storage_dtype().unwrap(), Dtype::F32);
        assert_eq!(back.plan.dtype, DT_F32);
        // ... and the wire ladder's defaults: f32 wire, no error-feedback.
        assert_eq!(back.plan.wire, WIRE_F32);
        assert!(back.ef.is_empty());
        // The v4 re-encoding of it is exactly 1 dtype byte per segment +
        // 1 plan dtype byte + 1 wire byte + the 4-byte empty epoch count
        // + the 4-byte empty error-feedback count longer.
        assert_eq!(
            to_bytes(&back).len(),
            out.len() + ck.layout.segments.len() + 10
        );
        // bf16 checkpoints cannot be downgraded to the all-f32 format.
        assert!(to_bytes_v1(&sample_with(Dtype::Bf16)).is_err());
        // Neither can q8-wire (error-feedback-carrying) ones.
        assert!(to_bytes_v1(&sample_q8()).is_err());
        // Nor elastic (epoch-carrying) ones.
        assert!(to_bytes_v1(&sample_elastic()).is_err());
    }

    /// Pre-ladder (v2) files — the byte layout PR-5/6-era checkpoints
    /// have on disk, reproduced by hand — load with the wire rung
    /// defaulted to the storage dtype and no error-feedback state, every
    /// value bit-exact.
    #[test]
    fn v2_files_load_with_wire_following_storage() {
        for dtype in [Dtype::F32, Dtype::Bf16] {
            let ck = sample_with(dtype);
            let mut out = Vec::new();
            out.extend_from_slice(MAGIC);
            put_u32(&mut out, V2);
            put_str(&mut out, &ck.layout_key);
            put_u64(&mut out, ck.layout.blob_len as u64);
            put_u64(&mut out, ck.layout.params_len as u64);
            put_u32(&mut out, ck.layout.segments.len() as u32);
            for s in &ck.layout.segments {
                put_str(&mut out, &s.name);
                put_str(&mut out, &s.kind);
                put_u32(&mut out, s.shape.len() as u32);
                for &d in &s.shape {
                    put_u64(&mut out, d as u64);
                }
                put_u64(&mut out, s.offset as u64);
                put_u64(&mut out, s.size as u64);
                out.push(dtype_code(s.dtype));
            }
            put_u64(&mut out, ck.step);
            out.push(ck.plan.production);
            out.push(ck.plan.order);
            out.push(ck.plan.granularity);
            out.push(ck.plan.mode);
            out.push(ck.plan.dtype);
            // v2: NO wire byte.
            put_str(&mut out, &ck.plan.opt);
            put_u64(&mut out, ck.plan.steps);
            put_u64(&mut out, ck.plan.bucket_elems);
            put_u32(&mut out, ck.plan.n_ranks);
            put_u32(&mut out, ck.plan.n_shards);
            put_f32(&mut out, ck.plan.lr);
            put_f32(&mut out, ck.plan.wd);
            put_f64(&mut out, ck.plan.fabric_alpha);
            put_f64(&mut out, ck.plan.fabric_bw);
            put_u64(&mut out, ck.plan.seed);
            put_u64(&mut out, ck.plan.cursor_group);
            put_u64(&mut out, ck.plan.cursor_task);
            // v2: NO error-feedback section.
            put_u64(&mut out, ck.blob.len() as u64);
            write_u16s(&mut out, ck.blob.prefix_bits());
            write_f32s(&mut out, ck.blob.f32_part());

            // The hand-rolled bytes ARE what the shared v2 encoder emits.
            assert_eq!(out, to_bytes_v2(&ck).unwrap());
            let back = from_bytes(&out).unwrap();
            assert_eq!(back, ck); // sample_with already spells wire=dtype
            assert_eq!(back.plan.wire, dtype_code(dtype));
            assert!(back.ef.is_empty());
            for (a, b) in ck.blob.to_f32().iter().zip(&back.blob.to_f32())
            {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            // The v4 re-encoding is exactly the wire byte + the 4-byte
            // empty epoch count + the 4-byte empty error-feedback count
            // longer.
            assert_eq!(to_bytes(&back).len(), out.len() + 9);
        }
        // The v2 format cannot spell a decoupled wire or carry residuals.
        let mut decoupled = sample_with(Dtype::F32);
        decoupled.plan.wire = WIRE_BF16;
        assert!(to_bytes_v2(&decoupled).is_err());
        assert!(to_bytes_v2(&sample_q8()).is_err());
        // Nor a membership schedule.
        assert!(to_bytes_v2(&sample_elastic()).is_err());
    }

    /// Pre-elastic (v3) files — the byte layout PR-7-era checkpoints
    /// have on disk, reproduced by hand — load with an empty
    /// (fixed-membership) epoch schedule, every value bit-exact.
    #[test]
    fn v3_files_load_with_fixed_membership() {
        for ck in [sample_with(Dtype::Bf16), sample_q8()] {
            let mut out = Vec::new();
            out.extend_from_slice(MAGIC);
            put_u32(&mut out, V3);
            put_str(&mut out, &ck.layout_key);
            put_u64(&mut out, ck.layout.blob_len as u64);
            put_u64(&mut out, ck.layout.params_len as u64);
            put_u32(&mut out, ck.layout.segments.len() as u32);
            for s in &ck.layout.segments {
                put_str(&mut out, &s.name);
                put_str(&mut out, &s.kind);
                put_u32(&mut out, s.shape.len() as u32);
                for &d in &s.shape {
                    put_u64(&mut out, d as u64);
                }
                put_u64(&mut out, s.offset as u64);
                put_u64(&mut out, s.size as u64);
                out.push(dtype_code(s.dtype));
            }
            put_u64(&mut out, ck.step);
            out.push(ck.plan.production);
            out.push(ck.plan.order);
            out.push(ck.plan.granularity);
            out.push(ck.plan.mode);
            out.push(ck.plan.dtype);
            out.push(ck.plan.wire);
            put_str(&mut out, &ck.plan.opt);
            put_u64(&mut out, ck.plan.steps);
            put_u64(&mut out, ck.plan.bucket_elems);
            put_u32(&mut out, ck.plan.n_ranks);
            put_u32(&mut out, ck.plan.n_shards);
            put_f32(&mut out, ck.plan.lr);
            put_f32(&mut out, ck.plan.wd);
            put_f64(&mut out, ck.plan.fabric_alpha);
            put_f64(&mut out, ck.plan.fabric_bw);
            put_u64(&mut out, ck.plan.seed);
            put_u64(&mut out, ck.plan.cursor_group);
            put_u64(&mut out, ck.plan.cursor_task);
            // v3: NO membership-epoch section.
            put_u32(&mut out, ck.ef.len() as u32);
            for e in &ck.ef {
                put_u64(&mut out, e.len() as u64);
                write_f32s(&mut out, e);
            }
            put_u64(&mut out, ck.blob.len() as u64);
            write_u16s(&mut out, ck.blob.prefix_bits());
            write_f32s(&mut out, ck.blob.f32_part());

            // The hand-rolled bytes ARE what the shared v3 encoder emits.
            assert_eq!(out, to_bytes_v3(&ck).unwrap());
            let back = from_bytes(&out).unwrap();
            assert_eq!(back, ck); // sample plans carry no epochs already
            assert!(back.plan.epochs.is_empty());
            // The v4 re-encoding is exactly the 4-byte empty epoch count
            // longer.
            assert_eq!(to_bytes(&back).len(), out.len() + 4);
        }
        // The v3 format cannot spell a membership schedule.
        assert!(to_bytes_v3(&sample_elastic()).is_err());
    }

    /// ADCP v4 round-trips the membership-epoch schedule bit-exactly,
    /// rejects malformed schedules, and sizes the error-feedback section
    /// by the CURRENT epoch's rank count.
    #[test]
    fn membership_epochs_round_trip_and_validation() {
        let ck = sample_elastic();
        let back = from_bytes(&to_bytes(&ck)).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.plan.epochs, vec![(4, 3), (9, 1)]);
        // The membership helpers walk the schedule deterministically.
        assert_eq!(ck.plan.ranks_at(1), 2);
        assert_eq!(ck.plan.ranks_at(4), 2);
        assert_eq!(ck.plan.ranks_at(5), 3);
        assert_eq!(ck.plan.ranks_at(9), 3);
        assert_eq!(ck.plan.ranks_at(10), 1);
        assert_eq!(ck.plan.ranks_at(12), 1);
        assert_eq!(ck.plan.current_ranks(0), 2);
        assert_eq!(ck.plan.current_ranks(4), 3); // next step is 5
        assert_eq!(ck.plan.current_ranks(12), 1);
        // Non-increasing boundaries are rejected.
        let mut unsorted = sample_elastic();
        unsorted.plan.epochs = vec![(9, 3), (4, 1)];
        assert!(from_bytes(&to_bytes(&unsorted)).is_err());
        let mut dup = sample_elastic();
        dup.plan.epochs = vec![(4, 3), (4, 1)];
        assert!(from_bytes(&to_bytes(&dup)).is_err());
        // Boundaries outside the run (0, or >= steps) are rejected.
        let mut zero = sample_elastic();
        zero.plan.epochs = vec![(0, 3)];
        assert!(from_bytes(&to_bytes(&zero)).is_err());
        let mut past = sample_elastic();
        past.plan.epochs = vec![(12, 3)]; // steps = 12; only 1..=11 valid
        assert!(from_bytes(&to_bytes(&past)).is_err());
        // A zero-rank epoch is rejected.
        let mut empty_epoch = sample_elastic();
        empty_epoch.plan.epochs = vec![(4, 0)];
        assert!(from_bytes(&to_bytes(&empty_epoch)).is_err());
        // save() applies the same rules before touching the disk.
        let path = std::env::temp_dir().join(format!(
            "adalomo_ckpt_epochs_{}.bin",
            std::process::id()
        ));
        assert!(save(&path, &zero).is_err());
        save(&path, &ck).unwrap();
        assert_eq!(load(&path).unwrap(), ck);
        std::fs::remove_file(path).ok();

        // q8 + epochs: the EF section is validated against the rank
        // count of the epoch the file resumes INTO, not epoch 0's.
        let mut q8 = sample_q8();
        q8.plan.epochs = vec![(4, 3), (9, 1)]; // step 7 resumes into 3 ranks
        assert!(
            from_bytes(&to_bytes(&q8)).is_err(),
            "2 EF ranks must not pass a 3-rank epoch"
        );
        q8.ef = (0..3)
            .map(|r| vec![r as f32 * 0.5; q8.layout.params_len])
            .collect();
        let back = from_bytes(&to_bytes(&q8)).unwrap();
        assert_eq!(back.ef.len(), 3);
        assert_eq!(back, q8);
    }

    /// ADCP v3 round-trips the q8 wire's per-rank error-feedback
    /// accumulators bit-exactly, and rejects inconsistent sections.
    #[test]
    fn error_feedback_round_trip_and_validation() {
        let ck = sample_q8();
        let bytes = to_bytes(&ck);
        let back = from_bytes(&bytes).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.plan.wire, WIRE_Q8);
        assert_eq!(back.ef.len(), ck.plan.n_ranks as usize);
        for (a, b) in ck.ef.iter().flatten().zip(back.ef.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Exact-wire plans must not carry residual state.
        let mut stray = sample_q8();
        stray.plan.wire = WIRE_F32;
        assert!(from_bytes(&to_bytes(&stray)).is_err());
        // Rank count must match the plan.
        let mut short = sample_q8();
        short.ef.pop();
        assert!(from_bytes(&to_bytes(&short)).is_err());
        // Accumulator length must match params_len.
        let mut ragged = sample_q8();
        ragged.ef[0].push(0.0);
        assert!(from_bytes(&to_bytes(&ragged)).is_err());
        // A q8 file with an EMPTY section stays loadable (a hand-written
        // pre-run checkpoint): residuals simply start from zero.
        let mut empty = sample_q8();
        empty.ef.clear();
        let back = from_bytes(&to_bytes(&empty)).unwrap();
        assert!(back.ef.is_empty());
        // save() applies the same rules before touching the disk.
        let path = std::env::temp_dir().join(format!(
            "adalomo_ckpt_ef_{}.bin",
            std::process::id()
        ));
        assert!(save(&path, &stray).is_err());
        save(&path, &ck).unwrap();
        assert_eq!(load(&path).unwrap(), ck);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn file_round_trip() {
        let ck = sample();
        let path = std::env::temp_dir()
            .join(format!("adalomo_engine_ckpt_{}.bin", std::process::id()));
        save(&path, &ck).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, ck);
        // Overwriting an existing checkpoint publishes atomically (temp
        // sibling + rename): the new contents land and no temp file
        // lingers next to the target.
        let mut ck2 = ck.clone();
        ck2.step = 9;
        save(&path, &ck2).unwrap();
        assert_eq!(load(&path).unwrap().step, 9);
        assert!(!temp_sibling(&path).exists());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn corrupt_inputs_fail_loudly() {
        let ck = sample();
        let bytes = to_bytes(&ck);
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(from_bytes(&bad).is_err());
        // Future version.
        let mut newer = bytes.clone();
        newer[4..8].copy_from_slice(&(VERSION + 1).to_le_bytes());
        assert!(from_bytes(&newer).is_err());
        // Truncated body.
        assert!(from_bytes(&bytes[..bytes.len() - 3]).is_err());
        // Trailing garbage.
        let mut long = bytes.clone();
        long.extend_from_slice(&[0u8; 4]);
        assert!(from_bytes(&long).is_err());
        // Mid-step cursor rejected.
        let mut mid = ck.clone();
        mid.plan.cursor_group = 1;
        assert!(from_bytes(&to_bytes(&mid)).is_err());
        // Plan dtype disagreeing with the layout tags is rejected.
        let mut skew = ck.clone();
        skew.plan.dtype = DT_BF16;
        assert!(from_bytes(&to_bytes(&skew)).is_err());
        // Blob/layout length mismatch rejected at save time.
        let mut short = ck.clone();
        short.blob = TypedBlob::from_parts(
            Dtype::F32,
            12,
            Vec::new(),
            vec![0.0; 19],
        )
        .unwrap();
        let path = std::env::temp_dir().join(format!(
            "adalomo_engine_ckpt_bad_{}.bin",
            std::process::id()
        ));
        assert!(save(&path, &short).is_err());
        // A blob stored at the wrong dtype for the layout is rejected too.
        let mut wrong = ck.clone();
        wrong.blob = TypedBlob::from_parts(
            Dtype::Bf16,
            12,
            vec![0u16; 12],
            vec![0.0; 8],
        )
        .unwrap();
        assert!(save(&path, &wrong).is_err());
        std::fs::remove_file(path).ok();
    }

    /// Fuzz-style sweep over mutated headers and every truncation: the
    /// reader must come back with `Ok` or a clean `Err` — never a panic,
    /// never an attempt to allocate a corrupt length (the bounded
    /// `count32`/`len64` reads run before every allocation they size).
    #[test]
    fn mutated_headers_never_panic() {
        let elastic_q8 = {
            let mut ck = sample_elastic();
            ck.plan.wire = WIRE_Q8;
            let ranks = ck.plan.current_ranks(ck.step) as usize;
            ck.ef = (0..ranks)
                .map(|r| vec![r as f32 * 1e-3; ck.layout.params_len])
                .collect();
            ck
        };
        for bytes in [
            to_bytes(&sample_with(Dtype::F32)),
            to_bytes(&sample_with(Dtype::Bf16)),
            to_bytes(&sample_q8()),
            to_bytes(&sample_elastic()),
            to_bytes(&elastic_q8),
        ] {
            for i in 0..bytes.len() {
                for flip in [0x01u8, 0x80, 0xFF] {
                    let mut m = bytes.clone();
                    m[i] ^= flip;
                    let _ = from_bytes(&m); // must not panic or abort
                }
            }
            for k in 0..bytes.len() {
                assert!(from_bytes(&bytes[..k]).is_err(), "truncated at {k}");
            }
            // Trailing garbage stays an error.
            let mut long = bytes.clone();
            long.extend_from_slice(&[0u8; 3]);
            assert!(from_bytes(&long).is_err());
        }
    }

    #[test]
    fn f32_codec_is_shared_and_strict() {
        let data = vec![0.5f32, -1.25, f32::MIN_POSITIVE, 3.0e8];
        let mut bytes = Vec::new();
        write_f32s(&mut bytes, &data);
        assert_eq!(bytes.len(), 16);
        let back = read_f32s(&bytes, 4).unwrap();
        for (a, b) in data.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(read_f32s(&bytes, 3).is_err());
        assert!(read_f32s(&bytes[..15], 4).is_err());
        // A length whose byte count would overflow usize errors instead
        // of wrapping the comparison (and never allocates).
        assert!(read_f32s(&bytes, usize::MAX / 2).is_err());
    }

    #[test]
    fn u16_codec_mirrors_the_f32_one() {
        let data = vec![0u16, 1, 0x3F80, 0xFFFF, 0x8000];
        let mut bytes = Vec::new();
        write_u16s(&mut bytes, &data);
        assert_eq!(bytes.len(), 10);
        assert_eq!(read_u16s(&bytes, 5).unwrap(), data);
        assert!(read_u16s(&bytes, 4).is_err());
        assert!(read_u16s(&bytes[..9], 5).is_err());
        assert!(read_u16s(&bytes, usize::MAX / 2 + 1).is_err());
    }
}
