//! Versioned binary checkpoints for the unified execution engine.
//!
//! A checkpoint freezes everything the engine needs to continue a run
//! bitwise-identically after a process restart: the [`Layout`] (so the
//! resumed process can rebuild the flat optimizer without a manifest),
//! the full state blob (parameters + optimizer state + metrics), the
//! completed-step counter, and a [`PlanRecord`] — the serialized form of
//! `coordinator::engine::ExecPlan` plus the position inside it. The
//! format is self-contained and little-endian throughout; the leading
//! `ADCP` magic + version word make incompatible readers fail loudly
//! instead of misparsing.
//!
//! This module sits BELOW the coordinator layer, so it cannot name
//! `ExecPlan` directly: [`PlanRecord`] is the plain-data mirror the
//! coordinator converts to and from. The small f32 codec here
//! ([`write_f32s`]/[`read_f32s`]) is shared with [`super::HostBlob`]'s
//! simpler params-only checkpoint so the two file formats cannot drift in
//! how they spell a float.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use super::manifest::{Layout, Segment};

/// File magic for engine checkpoints ("ADalomo CheckPoint").
pub const MAGIC: &[u8; 4] = b"ADCP";

/// Current format version. Readers reject anything newer; the version is
/// bumped whenever a field is added or re-encoded.
pub const VERSION: u32 = 1;

/// Plain-data mirror of the coordinator's `ExecPlan`, plus the position
/// inside it. Enum axes are stored as u8 codes (see the `PROD_*`/`ORD_*`/
/// `GRAN_*`/`MODE_*` constants); the optimizer is stored by name so new
/// kinds never renumber old files.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanRecord {
    /// Gradient production: [`PROD_FULL_IMAGE`] | [`PROD_GROUPED`].
    pub production: u8,
    /// Exchange order: [`ORD_ASCENDING`] | [`ORD_DESCENDING`].
    pub order: u8,
    /// Step granularity: [`GRAN_WHOLE_IMAGE`] | [`GRAN_TASKS`] |
    /// [`GRAN_GROUPS`].
    pub granularity: u8,
    /// Shard plan: [`MODE_SEGMENTS`] | [`MODE_CONTIGUOUS`].
    pub mode: u8,
    /// Optimizer name (`OptKind::name()` spelling).
    pub opt: String,
    /// Total steps the plan runs for.
    pub steps: u64,
    /// Exchange bucket size in f32 elements (tasks granularity).
    pub bucket_elems: u64,
    pub n_ranks: u32,
    pub n_shards: u32,
    pub lr: f32,
    pub wd: f32,
    /// Fabric model: per-hop latency (s) and per-link bandwidth (B/s).
    pub fabric_alpha: f64,
    pub fabric_bw: f64,
    /// Source seed for deterministic host-mirror gradient streams — what
    /// lets a resumed CLI run reconstruct identical rank sources.
    pub seed: u64,
    /// Position inside the interrupted step: fused-group and fused-order
    /// task cursors. Version-1 writers only checkpoint at step
    /// boundaries, so both are always zero — readers validate that
    /// rather than silently resuming mid-step.
    pub cursor_group: u64,
    pub cursor_task: u64,
}

pub const PROD_FULL_IMAGE: u8 = 0;
pub const PROD_GROUPED: u8 = 1;
pub const ORD_ASCENDING: u8 = 0;
pub const ORD_DESCENDING: u8 = 1;
pub const GRAN_WHOLE_IMAGE: u8 = 0;
pub const GRAN_TASKS: u8 = 1;
pub const GRAN_GROUPS: u8 = 2;
pub const MODE_SEGMENTS: u8 = 0;
pub const MODE_CONTIGUOUS: u8 = 1;

/// Everything a checkpoint file holds.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Layout key the blob was trained under (`preset/opt` spelling).
    pub layout_key: String,
    pub layout: Layout,
    /// Completed optimizer steps at save time.
    pub step: u64,
    pub plan: PlanRecord,
    /// Full blob: parameter, optimizer-state and metrics regions.
    pub blob: Vec<f32>,
}

// --- little-endian writers/readers -------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Append `data` as raw little-endian f32s (4 bytes each, no length
/// prefix — callers write their own counts).
pub fn write_f32s(out: &mut Vec<u8>, data: &[f32]) {
    out.reserve(data.len() * 4);
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Decode exactly `n` little-endian f32s; `bytes` must hold exactly
/// `4 * n` bytes (a trailing-garbage or truncated body is an error, not a
/// partial read).
pub fn read_f32s(bytes: &[u8], n: usize) -> Result<Vec<f32>> {
    ensure!(
        bytes.len() == n * 4,
        "f32 body holds {} bytes, expected {}",
        bytes.len(),
        n * 4
    );
    let mut data = Vec::with_capacity(n);
    for chunk in bytes.chunks_exact(4) {
        data.push(f32::from_le_bytes(chunk.try_into()?));
    }
    Ok(data)
}

/// Bounds-checked cursor over a checkpoint body.
struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.pos + n <= self.bytes.len(),
            "truncated checkpoint (need {} bytes at offset {}, have {})",
            n,
            self.pos,
            self.bytes.len()
        );
        let piece = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(piece)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into()?))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into()?))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into()?))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into()?))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        Ok(String::from_utf8(self.take(n)?.to_vec())?)
    }

    fn usize64(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }
}

/// Serialize `ck` into the version-1 byte layout.
pub fn to_bytes(ck: &Checkpoint) -> Vec<u8> {
    encode(&ck.layout_key, &ck.layout, ck.step, &ck.plan, &ck.blob)
}

/// The version-1 encoder over borrowed parts — what [`write`] uses so
/// the engine can checkpoint without cloning its blob first.
fn encode(
    layout_key: &str,
    layout: &Layout,
    step: u64,
    plan: &PlanRecord,
    blob: &[f32],
) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + blob.len() * 4);
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    put_str(&mut out, layout_key);
    // Layout.
    put_u64(&mut out, layout.blob_len as u64);
    put_u64(&mut out, layout.params_len as u64);
    put_u32(&mut out, layout.segments.len() as u32);
    for s in &layout.segments {
        put_str(&mut out, &s.name);
        put_str(&mut out, &s.kind);
        put_u32(&mut out, s.shape.len() as u32);
        for &d in &s.shape {
            put_u64(&mut out, d as u64);
        }
        put_u64(&mut out, s.offset as u64);
        put_u64(&mut out, s.size as u64);
    }
    put_u64(&mut out, step);
    // Plan record.
    out.push(plan.production);
    out.push(plan.order);
    out.push(plan.granularity);
    out.push(plan.mode);
    put_str(&mut out, &plan.opt);
    put_u64(&mut out, plan.steps);
    put_u64(&mut out, plan.bucket_elems);
    put_u32(&mut out, plan.n_ranks);
    put_u32(&mut out, plan.n_shards);
    put_f32(&mut out, plan.lr);
    put_f32(&mut out, plan.wd);
    put_f64(&mut out, plan.fabric_alpha);
    put_f64(&mut out, plan.fabric_bw);
    put_u64(&mut out, plan.seed);
    put_u64(&mut out, plan.cursor_group);
    put_u64(&mut out, plan.cursor_task);
    // Blob.
    put_u64(&mut out, blob.len() as u64);
    write_f32s(&mut out, blob);
    out
}

/// Parse a version-1 checkpoint, validating magic, version, internal
/// layout consistency and exact body length.
pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
    ensure!(
        bytes.len() >= 8 && &bytes[..4] == MAGIC,
        "not an adalomo engine checkpoint (bad magic)"
    );
    let mut r = Reader { bytes, pos: 4 };
    let version = r.u32()?;
    ensure!(
        version == VERSION,
        "checkpoint version {version} unsupported (this build reads {VERSION})"
    );
    let layout_key = r.str()?;
    let blob_len = r.usize64()?;
    let params_len = r.usize64()?;
    let n_segments = r.u32()? as usize;
    let mut segments = Vec::with_capacity(n_segments);
    for _ in 0..n_segments {
        let name = r.str()?;
        let kind = r.str()?;
        let ndim = r.u32()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.usize64()?);
        }
        let offset = r.usize64()?;
        let size = r.usize64()?;
        segments.push(Segment { name, kind, shape, offset, size });
    }
    let layout = Layout { blob_len, params_len, segments };
    validate_layout(&layout)?;
    let step = r.u64()?;
    let plan = PlanRecord {
        production: r.u8()?,
        order: r.u8()?,
        granularity: r.u8()?,
        mode: r.u8()?,
        opt: r.str()?,
        steps: r.u64()?,
        bucket_elems: r.u64()?,
        n_ranks: r.u32()?,
        n_shards: r.u32()?,
        lr: r.f32()?,
        wd: r.f32()?,
        fabric_alpha: r.f64()?,
        fabric_bw: r.f64()?,
        seed: r.u64()?,
        cursor_group: r.u64()?,
        cursor_task: r.u64()?,
    };
    ensure!(
        plan.cursor_group == 0 && plan.cursor_task == 0,
        "mid-step checkpoint (group cursor {}, task cursor {}): version-1 \
         readers only resume at step boundaries",
        plan.cursor_group,
        plan.cursor_task
    );
    let n = r.usize64()?;
    ensure!(
        n == layout.blob_len,
        "checkpoint blob holds {n} floats, layout says {}",
        layout.blob_len
    );
    let blob = read_f32s(&bytes[r.pos..], n)?;
    Ok(Checkpoint { layout_key, layout, step, plan, blob })
}

/// The serialized layout must be internally consistent before anything
/// trusts its offsets: segments tile `[0, blob_len)` exactly and the
/// parameter region is a prefix.
fn validate_layout(layout: &Layout) -> Result<()> {
    let mut off = 0usize;
    for s in &layout.segments {
        ensure!(
            s.offset == off,
            "checkpoint layout: segment {} at offset {} (expected {off})",
            s.name,
            s.offset
        );
        ensure!(
            s.size == s.shape.iter().product::<usize>().max(1),
            "checkpoint layout: segment {} size {} != shape {:?}",
            s.name,
            s.size,
            s.shape
        );
        off += s.size;
    }
    ensure!(
        off == layout.blob_len,
        "checkpoint layout: segments cover {off} of {} floats",
        layout.blob_len
    );
    ensure!(
        layout.params_len <= layout.blob_len,
        "checkpoint layout: params_len {} > blob_len {}",
        layout.params_len,
        layout.blob_len
    );
    Ok(())
}

/// Write `ck` to `path` crash-safely (see [`write`]).
pub fn save(path: &Path, ck: &Checkpoint) -> Result<()> {
    write(path, &ck.layout_key, &ck.layout, ck.step, &ck.plan, &ck.blob)
}

/// [`save`] over borrowed parts: validates and serializes without the
/// caller assembling an owned [`Checkpoint`] first — the engine's
/// checkpoint path uses this so the state blob (its largest object) is
/// never cloned just to be written out.
///
/// The write is crash-safe: bytes go to a same-directory temp name and
/// are renamed over `path` only once fully written, so a kill mid-save
/// can never leave a torn file at the final path (nor destroy the
/// previous checkpoint there) — that torn file would otherwise defeat
/// the restart-survival guarantee checkpoints exist for.
pub fn write(
    path: &Path,
    layout_key: &str,
    layout: &Layout,
    step: u64,
    plan: &PlanRecord,
    blob: &[f32],
) -> Result<()> {
    ensure!(
        blob.len() == layout.blob_len,
        "checkpoint blob {} floats != layout {}",
        blob.len(),
        layout.blob_len
    );
    validate_layout(layout)?;
    let tmp = temp_sibling(path);
    std::fs::write(&tmp, encode(layout_key, layout, step, plan, blob))
        .with_context(|| format!("write checkpoint {tmp:?}"))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publish checkpoint {path:?}"))
}

/// Same-directory temp name (rename is only atomic within a filesystem);
/// the pid keeps concurrent writers from clobbering each other's
/// in-flight bytes.
fn temp_sibling(path: &Path) -> std::path::PathBuf {
    let mut name = path
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_else(|| "checkpoint".into());
    name.push(format!(".{}.tmp", std::process::id()));
    path.with_file_name(name)
}

/// Read and validate a checkpoint file.
pub fn load(path: &Path) -> Result<Checkpoint> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("read checkpoint {path:?}"))?;
    if bytes.len() >= 4 && &bytes[..4] != MAGIC {
        bail!(
            "{path:?} is not an engine checkpoint (HostBlob-style files \
             start with ADLM, engine checkpoints with ADCP)"
        );
    }
    from_bytes(&bytes)
        .with_context(|| format!("parse checkpoint {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let segments = vec![
            Segment {
                name: "w".into(),
                kind: "param".into(),
                shape: vec![2, 3],
                offset: 0,
                size: 6,
            },
            Segment {
                name: "w@v".into(),
                kind: "state".into(),
                shape: vec![6],
                offset: 6,
                size: 6,
            },
            Segment {
                name: "metrics".into(),
                kind: "metric".into(),
                shape: vec![8],
                offset: 12,
                size: 8,
            },
        ];
        let layout = Layout { blob_len: 20, params_len: 6, segments };
        Checkpoint {
            layout_key: "nano/adalomo".into(),
            layout,
            step: 7,
            plan: PlanRecord {
                production: PROD_GROUPED,
                order: ORD_DESCENDING,
                granularity: GRAN_TASKS,
                mode: MODE_CONTIGUOUS,
                opt: "adalomo".into(),
                steps: 12,
                bucket_elems: 64,
                n_ranks: 2,
                n_shards: 3,
                lr: 1e-2,
                wd: 0.01,
                fabric_alpha: 8e-6,
                fabric_bw: 170e9,
                seed: 42,
                cursor_group: 0,
                cursor_task: 0,
            },
            blob: (0..20).map(|i| i as f32 * 0.25 - 1.0).collect(),
        }
    }

    #[test]
    fn round_trip_is_exact() {
        let ck = sample();
        let bytes = to_bytes(&ck);
        let back = from_bytes(&bytes).unwrap();
        assert_eq!(back, ck);
        // Exact float bits survive, not just approximate values.
        for (a, b) in ck.blob.iter().zip(&back.blob) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Serialization is deterministic: same checkpoint, same bytes.
        assert_eq!(bytes, to_bytes(&back));
    }

    #[test]
    fn file_round_trip() {
        let ck = sample();
        let path = std::env::temp_dir()
            .join(format!("adalomo_engine_ckpt_{}.bin", std::process::id()));
        save(&path, &ck).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back, ck);
        // Overwriting an existing checkpoint publishes atomically (temp
        // sibling + rename): the new contents land and no temp file
        // lingers next to the target.
        let mut ck2 = ck.clone();
        ck2.step = 9;
        save(&path, &ck2).unwrap();
        assert_eq!(load(&path).unwrap().step, 9);
        assert!(!temp_sibling(&path).exists());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn corrupt_inputs_fail_loudly() {
        let ck = sample();
        let bytes = to_bytes(&ck);
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(from_bytes(&bad).is_err());
        // Future version.
        let mut newer = bytes.clone();
        newer[4..8].copy_from_slice(&(VERSION + 1).to_le_bytes());
        assert!(from_bytes(&newer).is_err());
        // Truncated body.
        assert!(from_bytes(&bytes[..bytes.len() - 3]).is_err());
        // Trailing garbage.
        let mut long = bytes.clone();
        long.extend_from_slice(&[0u8; 4]);
        assert!(from_bytes(&long).is_err());
        // Mid-step cursor rejected.
        let mut mid = ck.clone();
        mid.plan.cursor_group = 1;
        assert!(from_bytes(&to_bytes(&mid)).is_err());
        // Blob/layout length mismatch rejected at save time.
        let mut short = ck.clone();
        short.blob.pop();
        let path = std::env::temp_dir().join(format!(
            "adalomo_engine_ckpt_bad_{}.bin",
            std::process::id()
        ));
        assert!(save(&path, &short).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn f32_codec_is_shared_and_strict() {
        let data = vec![0.5f32, -1.25, f32::MIN_POSITIVE, 3.0e8];
        let mut bytes = Vec::new();
        write_f32s(&mut bytes, &data);
        assert_eq!(bytes.len(), 16);
        let back = read_f32s(&bytes, 4).unwrap();
        for (a, b) in data.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(read_f32s(&bytes, 3).is_err());
        assert!(read_f32s(&bytes[..15], 4).is_err());
    }
}
