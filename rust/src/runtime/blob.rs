//! Host-side view of the training-state blob: checkpointing, optimizer
//! conversion, and segment access via the manifest layout.
//!
//! The blob lives on device during training; this type only appears at
//! checkpoint boundaries (save/load/repack) — never on the step path.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{TensorView, TensorViewMut};

use super::manifest::Layout;

#[derive(Debug, Clone)]
pub struct HostBlob {
    pub data: Vec<f32>,
    pub layout_key: String,
}

impl HostBlob {
    pub fn new(data: Vec<f32>, layout_key: &str, layout: &Layout) -> Result<Self> {
        if data.len() != layout.blob_len {
            bail!(
                "blob length {} != layout {} ({})",
                data.len(),
                layout.blob_len,
                layout_key
            );
        }
        Ok(HostBlob { data, layout_key: layout_key.to_string() })
    }

    /// View one segment's data.
    pub fn segment<'a>(&'a self, layout: &Layout, name: &str) -> Result<&'a [f32]> {
        let seg = layout
            .segment(name)
            .with_context(|| format!("no segment {name:?}"))?;
        Ok(&self.data[seg.offset..seg.offset + seg.size])
    }

    /// Shape-aware zero-copy view of one segment.
    pub fn segment_view<'a>(
        &'a self,
        layout: &'a Layout,
        name: &str,
    ) -> Result<TensorView<'a>> {
        let seg = layout
            .segment(name)
            .with_context(|| format!("no segment {name:?}"))?;
        TensorView::from_slice(
            &seg.shape,
            &self.data[seg.offset..seg.offset + seg.size],
        )
    }

    /// Shape-aware zero-copy mutable view of one segment.
    pub fn segment_view_mut<'a>(
        &'a mut self,
        layout: &'a Layout,
        name: &str,
    ) -> Result<TensorViewMut<'a>> {
        let seg = layout
            .segment(name)
            .with_context(|| format!("no segment {name:?}"))?;
        TensorViewMut::from_slice_mut(
            &seg.shape,
            &mut self.data[seg.offset..seg.offset + seg.size],
        )
    }

    /// The leading parameter region (param + frozen).
    pub fn params<'a>(&'a self, layout: &Layout) -> &'a [f32] {
        &self.data[..layout.params_len]
    }

    /// Mutable parameter region — what local-SGD averaging splices.
    pub fn params_mut<'a>(&'a mut self, layout: &Layout) -> &'a mut [f32] {
        &mut self.data[..layout.params_len]
    }

    /// The optimizer-state region (between parameters and metrics).
    pub fn state_region<'a>(&'a self, layout: &Layout) -> &'a [f32] {
        &self.data[layout.params_len..layout.metrics_offset()]
    }

    /// Zero-copy view of an arbitrary half-open blob range — bucket
    /// granularity for the async pipeline, which exchanges fixed-size
    /// ranges that ignore segment boundaries.
    pub fn range<'a>(&'a self, lo: usize, hi: usize) -> Result<&'a [f32]> {
        if lo > hi || hi > self.data.len() {
            bail!("range [{lo}, {hi}) outside blob of {}", self.data.len());
        }
        Ok(&self.data[lo..hi])
    }

    /// Mutable counterpart of [`range`](Self::range) — what a reduced
    /// gradient bucket is spliced through.
    pub fn range_mut<'a>(
        &'a mut self,
        lo: usize,
        hi: usize,
    ) -> Result<&'a mut [f32]> {
        if lo > hi || hi > self.data.len() {
            bail!("range [{lo}, {hi}) outside blob of {}", self.data.len());
        }
        Ok(&mut self.data[lo..hi])
    }

    pub fn metrics<'a>(&'a self, layout: &Layout) -> &'a [f32] {
        &self.data[layout.metrics_offset()..]
    }

    /// Repack this blob's *parameters* into a different optimizer's layout
    /// (fresh zero state) — the checkpoint-conversion path used when e.g.
    /// instruction tuning (AdaLomo) starts from a scratch-pre-trained
    /// (AdamW) checkpoint. Both layouts must share the parameter prefix.
    pub fn repack(&self, from: &Layout, to: &Layout, to_key: &str) -> Result<HostBlob> {
        // Verify the shared prefix really is shared (names + shapes).
        let from_params: Vec<_> = from
            .segments
            .iter()
            .filter(|s| s.kind == "param" || s.kind == "frozen")
            .collect();
        let to_params: Vec<_> = to
            .segments
            .iter()
            .filter(|s| s.kind == "param" || s.kind == "frozen")
            .collect();
        let shared = from_params.len().min(to_params.len());
        for i in 0..shared {
            if from_params[i].name != to_params[i].name
                || from_params[i].shape != to_params[i].shape
            {
                bail!(
                    "layouts disagree at parameter {} ({} vs {})",
                    i,
                    from_params[i].name,
                    to_params[i].name
                );
            }
        }
        let mut data = vec![0f32; to.blob_len];
        let ncopy = from.params_len.min(to.params_len);
        data[..ncopy].copy_from_slice(&self.data[..ncopy]);
        HostBlob::new(data, to_key, to)
    }

    /// Binary checkpoint: little-endian f32s, preceded by a short header.
    /// The float codec is shared with the engine checkpoints in
    /// [`super::checkpoint`] so the two formats cannot drift.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut bytes =
            Vec::with_capacity(16 + self.layout_key.len() + self.data.len() * 4);
        bytes.extend_from_slice(b"ADLM");
        bytes.extend_from_slice(&(self.layout_key.len() as u32).to_le_bytes());
        bytes.extend_from_slice(self.layout_key.as_bytes());
        bytes.extend_from_slice(&(self.data.len() as u64).to_le_bytes());
        super::checkpoint::write_f32s(&mut bytes, &self.data);
        std::fs::write(path, bytes).with_context(|| format!("write {path:?}"))
    }

    pub fn load(path: &Path) -> Result<HostBlob> {
        let bytes =
            std::fs::read(path).with_context(|| format!("read {path:?}"))?;
        if bytes.len() < 16 || &bytes[..4] != b"ADLM" {
            bail!("{path:?}: not an adalomo checkpoint");
        }
        let klen = u32::from_le_bytes(bytes[4..8].try_into()?) as usize;
        // Bounds-checked header reads: a header truncated mid-field is a
        // reportable error, never a slice panic.
        let header_end = 8usize
            .checked_add(klen)
            .and_then(|o| o.checked_add(8))
            .filter(|&end| end <= bytes.len());
        let Some(header_end) = header_end else {
            bail!("{path:?}: truncated checkpoint");
        };
        let off = 8 + klen;
        let layout_key = String::from_utf8(bytes[8..off].to_vec())?;
        let n = u64::from_le_bytes(bytes[off..header_end].try_into()?) as usize;
        let data = super::checkpoint::read_f32s(&bytes[header_end..], n)
            .with_context(|| format!("{path:?}: truncated checkpoint"))?;
        Ok(HostBlob { data, layout_key })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Segment;

    fn layout(state: usize) -> Layout {
        let segments = vec![
            Segment {
                name: "w".into(),
                kind: "param".into(),
                shape: vec![2, 3],
                offset: 0,
                size: 6,
            },
            Segment {
                name: "w@s".into(),
                kind: "state".into(),
                shape: vec![state],
                offset: 6,
                size: state,
            },
            Segment {
                name: "metrics".into(),
                kind: "metric".into(),
                shape: vec![8],
                offset: 6 + state,
                size: 8,
            },
        ];
        Layout { blob_len: 14 + state, params_len: 6, segments }
    }

    #[test]
    fn segment_views() {
        let l = layout(4);
        let blob = HostBlob::new(
            (0..18).map(|i| i as f32).collect(),
            "t/x",
            &l,
        )
        .unwrap();
        assert_eq!(blob.params(&l), &[0., 1., 2., 3., 4., 5.]);
        assert_eq!(blob.segment(&l, "w@s").unwrap(), &[6., 7., 8., 9.]);
        assert_eq!(blob.metrics(&l).len(), 8);
        assert!(blob.segment(&l, "nope").is_err());
        // Shape-aware zero-copy views.
        let v = blob.segment_view(&l, "w").unwrap();
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.sum(), 15.0);
        assert_eq!(blob.state_region(&l), &[6., 7., 8., 9.]);
        let mut blob2 = blob.clone();
        blob2.segment_view_mut(&l, "w").unwrap().axpy(1.0, &[1.0; 6]);
        assert_eq!(blob2.params(&l), &[1., 2., 3., 4., 5., 6.]);
        blob2.params_mut(&l)[0] = 9.0;
        assert_eq!(blob2.data[0], 9.0);
    }

    #[test]
    fn state_segment_lookup_by_suffix() {
        let l = layout(4);
        assert_eq!(l.state_segment("w", "s").unwrap().size, 4);
        assert!(l.state_segment("w", "m").is_none());
        let names: Vec<_> =
            l.state_segments("w").map(|s| s.name.clone()).collect();
        assert_eq!(names, vec!["w@s"]);
        // Prefix collisions must not match ("w2@s" is not state of "w").
        assert_eq!(l.state_segments("w@").count(), 0);
        assert_eq!(l.shardable_len(), l.metrics_offset());
    }

    #[test]
    fn wrong_len_rejected() {
        assert!(HostBlob::new(vec![0.0; 3], "t/x", &layout(4)).is_err());
    }

    #[test]
    fn bucket_range_views() {
        let l = layout(4);
        let mut blob = HostBlob::new(
            (0..18).map(|i| i as f32).collect(),
            "t/x",
            &l,
        )
        .unwrap();
        // A bucket that straddles the param/state boundary.
        assert_eq!(blob.range(4, 8).unwrap(), &[4., 5., 6., 7.]);
        blob.range_mut(4, 8).unwrap().fill(0.5);
        assert_eq!(blob.data[4..8], [0.5, 0.5, 0.5, 0.5]);
        assert!(blob.range(4, 99).is_err());
        assert!(blob.range(8, 4).is_err());
        // The layout side: which segments does the bucket touch?
        let names: Vec<_> = l
            .segments_in_range(4, 8)
            .map(|s| s.name.clone())
            .collect();
        assert_eq!(names, vec!["w", "w@s"]);
        // Empty ranges overlap nothing, even inside a segment's interior.
        assert_eq!(l.segments_in_range(6, 6).count(), 0);
        assert_eq!(l.segments_in_range(3, 3).count(), 0);
        let all: Vec<_> = l
            .segments_in_range(0, l.blob_len)
            .map(|s| s.name.clone())
            .collect();
        assert_eq!(all, vec!["w", "w@s", "metrics"]);
    }

    #[test]
    fn repack_copies_params_zeroes_state() {
        let from = layout(4);
        let to = layout(9);
        let blob = HostBlob::new(
            (0..18).map(|i| i as f32 + 1.0).collect(),
            "t/a",
            &from,
        )
        .unwrap();
        let out = blob.repack(&from, &to, "t/b").unwrap();
        assert_eq!(out.data.len(), 23);
        assert_eq!(&out.data[..6], blob.params(&from));
        assert!(out.data[6..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn truncated_checkpoint_errors_instead_of_panicking() {
        let path = std::env::temp_dir().join(format!(
            "adalomo_trunc_ckpt_{}.bin",
            std::process::id()
        ));
        // Magic + a key length pointing far past the end of the file.
        let mut bytes = b"ADLM".to_vec();
        bytes.extend_from_slice(&200u32.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 8]);
        std::fs::write(&path, &bytes).unwrap();
        let err = HostBlob::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"));
        // Valid header, float count larger than the body.
        let mut bytes = b"ADLM".to_vec();
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.push(b'k');
        bytes.extend_from_slice(&100u64.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 4]);
        std::fs::write(&path, &bytes).unwrap();
        assert!(HostBlob::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn save_load_roundtrip() {
        let l = layout(2);
        let blob = HostBlob::new(
            (0..16).map(|i| i as f32 * 0.5).collect(),
            "nano/adalomo",
            &l,
        )
        .unwrap();
        let path = std::env::temp_dir()
            .join(format!("adalomo_ckpt_{}.bin", std::process::id()));
        blob.save(&path).unwrap();
        let loaded = HostBlob::load(&path).unwrap();
        assert_eq!(loaded.layout_key, "nano/adalomo");
        assert_eq!(loaded.data, blob.data);
        std::fs::remove_file(path).ok();
    }
}
