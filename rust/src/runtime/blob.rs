//! Host-side views of the training-state blob.
//!
//! Load paths here parse **untrusted bytes**, so — like
//! `runtime/checkpoint.rs` — this file's `analyze` panic budget is
//! pinned at zero `unwrap()`/`expect()` in non-test code
//! (docs/ANALYSIS.md): parse failures surface as `anyhow` errors, never
//! panics.
//!
//! Two types share this module:
//!
//! * [`HostBlob`] — the all-f32 checkpoint-boundary view (save/load/
//!   repack, segment access via the manifest layout). The blob lives on
//!   device during PJRT training; this type never sits on a step path.
//! * [`TypedBlob`] — dtype-aware storage for the host engine's training
//!   blob: the shardable prefix (parameters + optimizer state) held at
//!   the layout's storage [`Dtype`], the metrics tail always f32 (exact
//!   counters). Reads widen (bf16 → f32 is exact); writes round to
//!   nearest even. This is what the unified engine trains and
//!   checkpoints, and where the paper's memory story becomes actual
//!   halved storage bytes rather than a modeled number.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::tensor::{
    bf16_to_f32, f32_to_bf16, Dtype, TensorView, TensorViewMut,
};

use super::manifest::Layout;

#[derive(Debug, Clone)]
pub struct HostBlob {
    pub data: Vec<f32>,
    pub layout_key: String,
}

impl HostBlob {
    pub fn new(data: Vec<f32>, layout_key: &str, layout: &Layout) -> Result<Self> {
        if data.len() != layout.blob_len {
            bail!(
                "blob length {} != layout {} ({})",
                data.len(),
                layout.blob_len,
                layout_key
            );
        }
        Ok(HostBlob { data, layout_key: layout_key.to_string() })
    }

    /// View one segment's data.
    pub fn segment<'a>(&'a self, layout: &Layout, name: &str) -> Result<&'a [f32]> {
        let seg = layout
            .segment(name)
            .with_context(|| format!("no segment {name:?}"))?;
        Ok(&self.data[seg.offset..seg.offset + seg.size])
    }

    /// Shape-aware zero-copy view of one segment.
    pub fn segment_view<'a>(
        &'a self,
        layout: &'a Layout,
        name: &str,
    ) -> Result<TensorView<'a>> {
        let seg = layout
            .segment(name)
            .with_context(|| format!("no segment {name:?}"))?;
        TensorView::from_slice(
            &seg.shape,
            &self.data[seg.offset..seg.offset + seg.size],
        )
    }

    /// Shape-aware zero-copy mutable view of one segment.
    pub fn segment_view_mut<'a>(
        &'a mut self,
        layout: &'a Layout,
        name: &str,
    ) -> Result<TensorViewMut<'a>> {
        let seg = layout
            .segment(name)
            .with_context(|| format!("no segment {name:?}"))?;
        TensorViewMut::from_slice_mut(
            &seg.shape,
            &mut self.data[seg.offset..seg.offset + seg.size],
        )
    }

    /// The leading parameter region (param + frozen).
    pub fn params<'a>(&'a self, layout: &Layout) -> &'a [f32] {
        &self.data[..layout.params_len]
    }

    /// Mutable parameter region — what local-SGD averaging splices.
    pub fn params_mut<'a>(&'a mut self, layout: &Layout) -> &'a mut [f32] {
        &mut self.data[..layout.params_len]
    }

    /// The optimizer-state region (between parameters and metrics).
    pub fn state_region<'a>(&'a self, layout: &Layout) -> &'a [f32] {
        &self.data[layout.params_len..layout.metrics_offset()]
    }

    /// Zero-copy view of an arbitrary half-open blob range — bucket
    /// granularity for the async pipeline, which exchanges fixed-size
    /// ranges that ignore segment boundaries.
    pub fn range<'a>(&'a self, lo: usize, hi: usize) -> Result<&'a [f32]> {
        if lo > hi || hi > self.data.len() {
            bail!("range [{lo}, {hi}) outside blob of {}", self.data.len());
        }
        Ok(&self.data[lo..hi])
    }

    /// Mutable counterpart of [`range`](Self::range) — what a reduced
    /// gradient bucket is spliced through.
    pub fn range_mut<'a>(
        &'a mut self,
        lo: usize,
        hi: usize,
    ) -> Result<&'a mut [f32]> {
        if lo > hi || hi > self.data.len() {
            bail!("range [{lo}, {hi}) outside blob of {}", self.data.len());
        }
        Ok(&mut self.data[lo..hi])
    }

    pub fn metrics<'a>(&'a self, layout: &Layout) -> &'a [f32] {
        &self.data[layout.metrics_offset()..]
    }

    /// Repack this blob's *parameters* into a different optimizer's layout
    /// (fresh zero state) — the checkpoint-conversion path used when e.g.
    /// instruction tuning (AdaLomo) starts from a scratch-pre-trained
    /// (AdamW) checkpoint. Both layouts must share the parameter prefix.
    pub fn repack(&self, from: &Layout, to: &Layout, to_key: &str) -> Result<HostBlob> {
        // Verify the shared prefix really is shared (names + shapes).
        let from_params: Vec<_> = from
            .segments
            .iter()
            .filter(|s| s.kind == "param" || s.kind == "frozen")
            .collect();
        let to_params: Vec<_> = to
            .segments
            .iter()
            .filter(|s| s.kind == "param" || s.kind == "frozen")
            .collect();
        let shared = from_params.len().min(to_params.len());
        for i in 0..shared {
            if from_params[i].name != to_params[i].name
                || from_params[i].shape != to_params[i].shape
            {
                bail!(
                    "layouts disagree at parameter {} ({} vs {})",
                    i,
                    from_params[i].name,
                    to_params[i].name
                );
            }
        }
        let mut data = vec![0f32; to.blob_len];
        let ncopy = from.params_len.min(to.params_len);
        data[..ncopy].copy_from_slice(&self.data[..ncopy]);
        HostBlob::new(data, to_key, to)
    }

    /// Binary checkpoint: little-endian f32s, preceded by a short header.
    /// The float codec is shared with the engine checkpoints in
    /// [`super::checkpoint`] so the two formats cannot drift.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut bytes =
            Vec::with_capacity(16 + self.layout_key.len() + self.data.len() * 4);
        bytes.extend_from_slice(b"ADLM");
        bytes.extend_from_slice(&(self.layout_key.len() as u32).to_le_bytes());
        bytes.extend_from_slice(self.layout_key.as_bytes());
        bytes.extend_from_slice(&(self.data.len() as u64).to_le_bytes());
        super::checkpoint::write_f32s(&mut bytes, &self.data);
        std::fs::write(path, bytes).with_context(|| format!("write {path:?}"))
    }

    pub fn load(path: &Path) -> Result<HostBlob> {
        let bytes =
            std::fs::read(path).with_context(|| format!("read {path:?}"))?;
        if bytes.len() < 16 || &bytes[..4] != b"ADLM" {
            bail!("{path:?}: not an adalomo checkpoint");
        }
        let klen = u32::from_le_bytes(bytes[4..8].try_into()?) as usize;
        // Bounds-checked header reads: a header truncated mid-field is a
        // reportable error, never a slice panic.
        let header_end = 8usize
            .checked_add(klen)
            .and_then(|o| o.checked_add(8))
            .filter(|&end| end <= bytes.len());
        let Some(header_end) = header_end else {
            bail!("{path:?}: truncated checkpoint");
        };
        let off = 8 + klen;
        let layout_key = String::from_utf8(bytes[8..off].to_vec())?;
        let n = u64::from_le_bytes(bytes[off..header_end].try_into()?) as usize;
        let data = super::checkpoint::read_f32s(&bytes[header_end..], n)
            .with_context(|| format!("{path:?}: truncated checkpoint"))?;
        Ok(HostBlob { data, layout_key })
    }

    /// Round this all-f32 blob into `dtype` storage for `layout` — the
    /// entry to the dtype-aware engine path (see [`TypedBlob`]).
    pub fn to_typed(&self, layout: &Layout, dtype: Dtype) -> Result<TypedBlob> {
        TypedBlob::from_f32(layout, &self.data, dtype)
    }
}

/// Dtype-aware training-blob storage (see the module docs): elements
/// `[0, split)` — the params + optimizer-state prefix — at the storage
/// dtype, elements `[split, len)` — the metrics tail — always f32.
///
/// Offsets into a `TypedBlob` are in ELEMENTS, exactly as in [`Layout`];
/// only the byte width behind them changes.
#[derive(Debug, Clone, PartialEq)]
pub struct TypedBlob {
    dtype: Dtype,
    /// Elements stored at `dtype` (the shardable-prefix length).
    split: usize,
    /// bf16 bit patterns of the prefix; empty for f32 storage.
    bits: Vec<u16>,
    /// f32 storage: the whole blob for f32, the metrics tail for bf16.
    f32s: Vec<f32>,
}

/// Mutable raw-storage view of a [`TypedBlob`] — the optimizer fast path
/// dispatches on this once per step and then works on plain slices.
pub enum BlobPartsMut<'a> {
    /// All-f32 storage: the full blob as one slice.
    F32(&'a mut [f32]),
    /// bf16 storage: the shardable prefix as raw bf16 bits plus the f32
    /// metrics tail.
    Bf16 {
        /// bf16 bit patterns of elements `[0, split)`.
        bits: &'a mut [u16],
        /// f32 elements `[split, len)` (the metrics region).
        tail: &'a mut [f32],
    },
}

impl TypedBlob {
    /// Round an f32 image into `dtype` storage for `layout`. This is the
    /// single lossy moment of a bf16 run (round-to-nearest-even per
    /// element); every later read widens exactly and every write rounds
    /// the same way, so two paths that perform identical f32 writes hold
    /// identical bits.
    pub fn from_f32(
        layout: &Layout,
        data: &[f32],
        dtype: Dtype,
    ) -> Result<TypedBlob> {
        ensure!(
            data.len() == layout.blob_len,
            "blob length {} != layout {}",
            data.len(),
            layout.blob_len
        );
        let split = layout.shardable_len();
        Ok(match dtype {
            Dtype::F32 => TypedBlob {
                dtype,
                split,
                bits: Vec::new(),
                f32s: data.to_vec(),
            },
            Dtype::Bf16 => TypedBlob {
                dtype,
                split,
                bits: data[..split].iter().map(|&x| f32_to_bf16(x)).collect(),
                f32s: data[split..].to_vec(),
            },
        })
    }

    /// Rebuild from raw storage parts — the checkpoint reader's bit-exact
    /// path (no conversion happens).
    pub fn from_parts(
        dtype: Dtype,
        split: usize,
        bits: Vec<u16>,
        f32s: Vec<f32>,
    ) -> Result<TypedBlob> {
        match dtype {
            Dtype::F32 => ensure!(
                bits.is_empty() && split <= f32s.len(),
                "f32 storage takes no bf16 bits and split {} <= len {}",
                split,
                f32s.len()
            ),
            Dtype::Bf16 => ensure!(
                bits.len() == split,
                "bf16 prefix holds {} elems, split says {}",
                bits.len(),
                split
            ),
        }
        Ok(TypedBlob { dtype, split, bits, f32s })
    }

    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Total elements (the layout's `blob_len`).
    pub fn len(&self) -> usize {
        match self.dtype {
            Dtype::F32 => self.f32s.len(),
            Dtype::Bf16 => self.bits.len() + self.f32s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Elements stored at the storage dtype (the shardable prefix).
    pub fn split(&self) -> usize {
        self.split
    }

    /// Actual bytes this blob occupies — the measured half of the paper's
    /// Table-1 memory story (`blob_bytes` in the bench gate).
    pub fn storage_bytes(&self) -> usize {
        self.bits.len() * 2 + self.f32s.len() * 4
    }

    /// Raw bf16 prefix bits (empty for f32 storage) — the checkpoint
    /// codec's zero-conversion view.
    pub fn prefix_bits(&self) -> &[u16] {
        &self.bits
    }

    /// The f32-stored elements: the whole blob for f32 storage, the
    /// metrics tail for bf16.
    pub fn f32_part(&self) -> &[f32] {
        &self.f32s
    }

    /// Widen-on-read: the full image at compute precision (exact for both
    /// dtypes — bf16 ⊂ f32).
    pub fn to_f32(&self) -> Vec<f32> {
        match self.dtype {
            Dtype::F32 => self.f32s.clone(),
            Dtype::Bf16 => {
                let mut out = Vec::with_capacity(self.len());
                out.extend(self.bits.iter().map(|&b| bf16_to_f32(b)));
                out.extend_from_slice(&self.f32s);
                out
            }
        }
    }

    /// Consuming form of [`Self::to_f32`]: for f32 storage the existing
    /// allocation moves out instead of being cloned (the common case on
    /// the engine's `into_blob` path).
    pub fn into_f32(self) -> Vec<f32> {
        match self.dtype {
            Dtype::F32 => self.f32s,
            Dtype::Bf16 => self.to_f32(),
        }
    }

    /// Widen-on-read view of the half-open element range `[lo, hi)` into
    /// `out` (which must hold exactly `hi - lo` elements).
    pub fn read_range(&self, lo: usize, hi: usize, out: &mut [f32]) -> Result<()> {
        ensure!(
            lo <= hi && hi <= self.len(),
            "range [{lo}, {hi}) outside blob of {}",
            self.len()
        );
        ensure!(
            out.len() == hi - lo,
            "output holds {} elems for a range of {}",
            out.len(),
            hi - lo
        );
        match self.dtype {
            Dtype::F32 => out.copy_from_slice(&self.f32s[lo..hi]),
            Dtype::Bf16 => {
                // Prefix overlap [plo, phi) and tail overlap [tlo, hi).
                let plo = lo.min(self.split);
                let phi = hi.min(self.split);
                for (o, &b) in out[..phi.saturating_sub(lo)]
                    .iter_mut()
                    .zip(&self.bits[plo..phi])
                {
                    *o = bf16_to_f32(b);
                }
                if hi > self.split {
                    let tlo = lo.max(self.split);
                    out[tlo - lo..].copy_from_slice(
                        &self.f32s[tlo - self.split..hi - self.split],
                    );
                }
            }
        }
        Ok(())
    }

    /// Round-on-write of the element range `[lo, hi)` from f32 values
    /// (round-to-nearest-even into a bf16-stored prefix; exact into the
    /// f32 tail).
    pub fn write_range(&mut self, lo: usize, hi: usize, src: &[f32]) -> Result<()> {
        ensure!(
            lo <= hi && hi <= self.len(),
            "range [{lo}, {hi}) outside blob of {}",
            self.len()
        );
        ensure!(
            src.len() == hi - lo,
            "source holds {} elems for a range of {}",
            src.len(),
            hi - lo
        );
        match self.dtype {
            Dtype::F32 => self.f32s[lo..hi].copy_from_slice(src),
            Dtype::Bf16 => {
                let plo = lo.min(self.split);
                let phi = hi.min(self.split);
                for (b, &s) in self.bits[plo..phi]
                    .iter_mut()
                    .zip(&src[..phi.saturating_sub(lo)])
                {
                    *b = f32_to_bf16(s);
                }
                if hi > self.split {
                    let tlo = lo.max(self.split);
                    self.f32s[tlo - self.split..hi - self.split]
                        .copy_from_slice(&src[tlo - lo..]);
                }
            }
        }
        Ok(())
    }

    /// Dtype-aware segment view: the named segment's values widened to
    /// compute precision.
    pub fn segment_f32(&self, layout: &Layout, name: &str) -> Result<Vec<f32>> {
        let seg = layout
            .segment(name)
            .with_context(|| format!("no segment {name:?}"))?;
        let mut out = vec![0f32; seg.size];
        self.read_range(seg.offset, seg.offset + seg.size, &mut out)?;
        Ok(out)
    }

    /// Dtype-aware segment write: round the f32 values into the named
    /// segment's storage.
    pub fn write_segment_f32(
        &mut self,
        layout: &Layout,
        name: &str,
        values: &[f32],
    ) -> Result<()> {
        let seg = layout
            .segment(name)
            .with_context(|| format!("no segment {name:?}"))?;
        self.write_range(seg.offset, seg.offset + seg.size, values)
    }

    /// Mutable raw-storage view — what the flat optimizer dispatches on.
    pub fn parts_mut(&mut self) -> BlobPartsMut<'_> {
        match self.dtype {
            Dtype::F32 => BlobPartsMut::F32(&mut self.f32s),
            Dtype::Bf16 => BlobPartsMut::Bf16 {
                bits: &mut self.bits,
                tail: &mut self.f32s,
            },
        }
    }

    /// All-f32 [`HostBlob`] view (checkpoint-boundary interchange).
    pub fn to_host(&self, layout_key: &str) -> HostBlob {
        HostBlob { data: self.to_f32(), layout_key: layout_key.to_string() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Segment;

    fn layout(state: usize) -> Layout {
        let segments = vec![
            Segment {
                name: "w".into(),
                kind: "param".into(),
                shape: vec![2, 3],
                offset: 0,
                size: 6,
                dtype: Dtype::F32,
            },
            Segment {
                name: "w@s".into(),
                kind: "state".into(),
                shape: vec![state],
                offset: 6,
                size: state,
                dtype: Dtype::F32,
            },
            Segment {
                name: "metrics".into(),
                kind: "metric".into(),
                shape: vec![8],
                offset: 6 + state,
                size: 8,
                dtype: Dtype::F32,
            },
        ];
        Layout { blob_len: 14 + state, params_len: 6, segments }
    }

    #[test]
    fn segment_views() {
        let l = layout(4);
        let blob = HostBlob::new(
            (0..18).map(|i| i as f32).collect(),
            "t/x",
            &l,
        )
        .unwrap();
        assert_eq!(blob.params(&l), &[0., 1., 2., 3., 4., 5.]);
        assert_eq!(blob.segment(&l, "w@s").unwrap(), &[6., 7., 8., 9.]);
        assert_eq!(blob.metrics(&l).len(), 8);
        assert!(blob.segment(&l, "nope").is_err());
        // Shape-aware zero-copy views.
        let v = blob.segment_view(&l, "w").unwrap();
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.sum(), 15.0);
        assert_eq!(blob.state_region(&l), &[6., 7., 8., 9.]);
        let mut blob2 = blob.clone();
        blob2.segment_view_mut(&l, "w").unwrap().axpy(1.0, &[1.0; 6]);
        assert_eq!(blob2.params(&l), &[1., 2., 3., 4., 5., 6.]);
        blob2.params_mut(&l)[0] = 9.0;
        assert_eq!(blob2.data[0], 9.0);
    }

    #[test]
    fn state_segment_lookup_by_suffix() {
        let l = layout(4);
        assert_eq!(l.state_segment("w", "s").unwrap().size, 4);
        assert!(l.state_segment("w", "m").is_none());
        let names: Vec<_> =
            l.state_segments("w").map(|s| s.name.clone()).collect();
        assert_eq!(names, vec!["w@s"]);
        // Prefix collisions must not match ("w2@s" is not state of "w").
        assert_eq!(l.state_segments("w@").count(), 0);
        assert_eq!(l.shardable_len(), l.metrics_offset());
    }

    #[test]
    fn wrong_len_rejected() {
        assert!(HostBlob::new(vec![0.0; 3], "t/x", &layout(4)).is_err());
    }

    #[test]
    fn bucket_range_views() {
        let l = layout(4);
        let mut blob = HostBlob::new(
            (0..18).map(|i| i as f32).collect(),
            "t/x",
            &l,
        )
        .unwrap();
        // A bucket that straddles the param/state boundary.
        assert_eq!(blob.range(4, 8).unwrap(), &[4., 5., 6., 7.]);
        blob.range_mut(4, 8).unwrap().fill(0.5);
        assert_eq!(blob.data[4..8], [0.5, 0.5, 0.5, 0.5]);
        assert!(blob.range(4, 99).is_err());
        assert!(blob.range(8, 4).is_err());
        // The layout side: which segments does the bucket touch?
        let names: Vec<_> = l
            .segments_in_range(4, 8)
            .map(|s| s.name.clone())
            .collect();
        assert_eq!(names, vec!["w", "w@s"]);
        // Empty ranges overlap nothing, even inside a segment's interior.
        assert_eq!(l.segments_in_range(6, 6).count(), 0);
        assert_eq!(l.segments_in_range(3, 3).count(), 0);
        let all: Vec<_> = l
            .segments_in_range(0, l.blob_len)
            .map(|s| s.name.clone())
            .collect();
        assert_eq!(all, vec!["w", "w@s", "metrics"]);
    }

    #[test]
    fn repack_copies_params_zeroes_state() {
        let from = layout(4);
        let to = layout(9);
        let blob = HostBlob::new(
            (0..18).map(|i| i as f32 + 1.0).collect(),
            "t/a",
            &from,
        )
        .unwrap();
        let out = blob.repack(&from, &to, "t/b").unwrap();
        assert_eq!(out.data.len(), 23);
        assert_eq!(&out.data[..6], blob.params(&from));
        assert!(out.data[6..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn truncated_checkpoint_errors_instead_of_panicking() {
        let path = std::env::temp_dir().join(format!(
            "adalomo_trunc_ckpt_{}.bin",
            std::process::id()
        ));
        // Magic + a key length pointing far past the end of the file.
        let mut bytes = b"ADLM".to_vec();
        bytes.extend_from_slice(&200u32.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 8]);
        std::fs::write(&path, &bytes).unwrap();
        let err = HostBlob::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"));
        // Valid header, float count larger than the body.
        let mut bytes = b"ADLM".to_vec();
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.push(b'k');
        bytes.extend_from_slice(&100u64.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 4]);
        std::fs::write(&path, &bytes).unwrap();
        assert!(HostBlob::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn save_load_roundtrip() {
        let l = layout(2);
        let blob = HostBlob::new(
            (0..16).map(|i| i as f32 * 0.5).collect(),
            "nano/adalomo",
            &l,
        )
        .unwrap();
        let path = std::env::temp_dir()
            .join(format!("adalomo_ckpt_{}.bin", std::process::id()));
        blob.save(&path).unwrap();
        let loaded = HostBlob::load(&path).unwrap();
        assert_eq!(loaded.layout_key, "nano/adalomo");
        assert_eq!(loaded.data, blob.data);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn typed_blob_f32_is_lossless_and_full_width() {
        let l = layout(4);
        let data: Vec<f32> = (0..18).map(|i| i as f32 * 0.31 - 2.0).collect();
        let tb = TypedBlob::from_f32(&l, &data, Dtype::F32).unwrap();
        assert_eq!(tb.dtype(), Dtype::F32);
        assert_eq!(tb.len(), l.blob_len);
        assert_eq!(tb.split(), l.shardable_len());
        assert_eq!(tb.storage_bytes(), l.blob_len * 4);
        assert_eq!(tb.to_f32(), data);
        assert!(tb.prefix_bits().is_empty());
        // Wrong-length image rejected, like HostBlob::new.
        assert!(TypedBlob::from_f32(&l, &data[..5], Dtype::F32).is_err());
    }

    #[test]
    fn typed_blob_bf16_halves_prefix_and_keeps_metrics_exact() {
        use crate::tensor::snap_bf16;
        let l = layout(4);
        let data: Vec<f32> =
            (0..18).map(|i| (i as f32 * 0.777).sin() * 3.0).collect();
        let tb = TypedBlob::from_f32(&l, &data, Dtype::Bf16).unwrap();
        assert_eq!(tb.dtype(), Dtype::Bf16);
        assert_eq!(tb.len(), 18);
        assert_eq!(tb.split(), 10);
        // 10 prefix elems x 2B + 8 metrics x 4B.
        assert_eq!(tb.storage_bytes(), 10 * 2 + 8 * 4);
        let widened = tb.to_f32();
        for (i, (&w, &x)) in widened.iter().zip(&data).enumerate() {
            if i < 10 {
                assert_eq!(w.to_bits(), snap_bf16(x).to_bits(), "elem {i}");
            } else {
                // Metrics tail is bit-exact f32.
                assert_eq!(w.to_bits(), x.to_bits(), "metrics elem {i}");
            }
        }
        // Dtype-aware segment views: widen-on-read...
        let seg = tb.segment_f32(&l, "w@s").unwrap();
        assert_eq!(seg.len(), 4);
        assert_eq!(seg[0].to_bits(), snap_bf16(data[6]).to_bits());
        // ...and round-on-write.
        let mut tb2 = tb.clone();
        tb2.write_segment_f32(&l, "w@s", &[1.001, 2.0, 3.0, 4.0]).unwrap();
        let back = tb2.segment_f32(&l, "w@s").unwrap();
        assert_eq!(back[0].to_bits(), snap_bf16(1.001).to_bits());
        assert_eq!(back[1], 2.0); // exactly representable
        assert!(tb2.segment_f32(&l, "nope").is_err());
        // HostBlob interchange round-trips through the typed storage.
        let host = tb.to_host("t/x");
        assert_eq!(host.data, widened);
        let again = host.to_typed(&l, Dtype::Bf16).unwrap();
        assert_eq!(again, tb); // re-rounding representable values: no-op
    }

    #[test]
    fn typed_blob_ranges_straddle_the_dtype_boundary() {
        let l = layout(4);
        let data: Vec<f32> = (0..18).map(|i| i as f32 + 0.25).collect();
        let mut tb = TypedBlob::from_f32(&l, &data, Dtype::Bf16).unwrap();
        // Read across the bf16/f32 boundary at element 10.
        let mut out = vec![0f32; 6];
        tb.read_range(8, 14, &mut out).unwrap();
        assert_eq!(out[2..], data[10..14]); // tail part exact
        // A pure-tail range must not touch the bf16 prefix.
        let mut m = vec![0f32; 4];
        tb.read_range(12, 16, &mut m).unwrap();
        assert_eq!(m, data[12..16]);
        // Write across the boundary, then read it back.
        tb.write_range(9, 12, &[5.0, 6.0, 7.0]).unwrap();
        let mut back = vec![0f32; 3];
        tb.read_range(9, 12, &mut back).unwrap();
        assert_eq!(back, [5.0, 6.0, 7.0]); // all exactly representable
        // Bounds are enforced.
        assert!(tb.read_range(4, 99, &mut m).is_err());
        assert!(tb.read_range(8, 4, &mut m).is_err());
        assert!(tb.write_range(0, 3, &[0.0; 2]).is_err());
        // from_parts validates its invariants.
        assert!(TypedBlob::from_parts(Dtype::Bf16, 3, vec![0; 2], vec![])
            .is_err());
        assert!(TypedBlob::from_parts(Dtype::F32, 1, vec![0; 1], vec![0.0])
            .is_err());
    }
}
