//! Infrastructure substrates.
//!
//! The offline crate registry ships only `xla` + `anyhow`, so the pieces a
//! production service would normally pull from crates.io are implemented
//! here: a JSON parser/writer ([`json`]), a deterministic PRNG ([`rng`]), a
//! CLI argument parser ([`cli`]), a criterion-style bench harness
//! ([`bench`]), paper-style ASCII tables ([`table`]) and summary statistics
//! ([`stats`]).

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
