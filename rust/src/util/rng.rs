//! Deterministic PRNG (PCG-XSH-RR 64/32) + sampling helpers.
//!
//! Every stochastic component in the coordinator (data generation,
//! shuffling, synthetic benchmarks) draws from an explicitly-seeded `Pcg32`
//! so whole experiments replay bit-identically from a run-config seed.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small state, solid statistics, and —
/// unlike `rand_core`-only registries — zero dependencies.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: seed-only constructor on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self, stream: u64) -> Pcg32 {
        Pcg32::new(self.next_u64(), stream.wrapping_mul(2654435761) | 1)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, bound) without modulo bias (Lemire's method).
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        loop {
            let x = self.next_u64() >> 11; // 53 random bits
            let prod = x.wrapping_mul(bound);
            // 53 bits against usize bounds used here (< 2^32) -> exact.
            if x < (u64::MAX >> 11) / bound * bound || bound.is_power_of_two()
            {
                return (prod >> 53) as usize;
            }
        }
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.f64()).max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
            as f32
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut target = self.f32() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut rng = Pcg32::seeded(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(11);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(5);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = Pcg32::seeded(9);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..2000 {
            counts[rng.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg32::seeded(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
