//! Tiny CLI argument parser (clap is not in the offline registry).
//!
//! Grammar: `binary <subcommand> [positional...] [--key value | --flag]`.
//! Values are fetched typed with defaults; unknown flags are rejected by
//! `finish()` so typos fail loudly.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    used: std::cell::RefCell<Vec<String>>,
}

impl Args {
    pub fn parse_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(name) = item.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    out.flags.insert(name.to_string(), iter.next().unwrap());
                } else {
                    // boolean flag
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(item);
            }
        }
        Ok(out)
    }

    fn mark(&self, key: &str) {
        self.used.borrow_mut().push(key.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key}: bad float {v:?}: {e}")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key}: bad integer {v:?}: {e}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key}: bad integer {v:?}: {e}")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Error on any flag that no handler consumed.
    pub fn finish(&self) -> Result<()> {
        let used = self.used.borrow();
        let unknown: Vec<&String> = self
            .flags
            .keys()
            .filter(|k| !used.contains(k))
            .collect();
        if !unknown.is_empty() {
            bail!("unknown flags: {unknown:?}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(items: &[&str]) -> Args {
        Args::parse(items.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["train", "--preset", "tiny", "--steps", "100",
                        "--verbose"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.str_or("preset", "nano"), "tiny");
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn equals_form() {
        let a = parse(&["--lr=0.001"]);
        assert_eq!(a.f32_or("lr", 0.0).unwrap(), 0.001);
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = parse(&["--oops", "1"]);
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["--steps", "abc"]);
        assert!(a.usize_or("steps", 0).is_err());
    }

    #[test]
    fn negative_value_consumed() {
        let a = parse(&["--lr", "-0.5"]);
        assert_eq!(a.f32_or("lr", 0.0).unwrap(), -0.5);
    }
}
