//! Summary statistics for bench results and metric aggregation.

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summarize over empty slice");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / n.max(2) as f64;
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile(&sorted, 0.50),
        p95: percentile(&sorted, 0.95),
    }
}

/// Percentile on an already-sorted slice (nearest-rank with interpolation).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Exponential moving average over a series (loss-curve smoothing).
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let next = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        out.push(next);
        acc = Some(next);
    }
    out
}

/// Ordinary least squares fit y = a + b x; returns (a, b).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return (sy / n, 0.0);
    }
    let b = (n * sxy - sx * sy) / denom;
    let a = (sy - b * sx) / n;
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_interp() {
        let v = [0.0, 10.0];
        assert_eq!(percentile(&v, 0.5), 5.0);
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 1.0), 10.0);
    }

    #[test]
    fn ema_smooths() {
        let out = ema(&[0.0, 1.0, 1.0], 0.5);
        assert_eq!(out, vec![0.0, 0.5, 0.75]);
    }

    #[test]
    fn linear_fit_exact() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }
}
