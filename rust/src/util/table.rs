//! Paper-style ASCII tables for the bench harness and `adalomo report`.

#[derive(Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Table { title: title.to_string(), ..Default::default() }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width != header width"
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                line.push_str(&format!("| {:width$} ", cells[i], width = widths[i]));
            }
            line.push('|');
            line
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with a sensible number of digits for table cells.
pub fn fnum(v: f64) -> String {
    if v == 0.0 {
        "0".to_string()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.1}")
    } else if v.abs() >= 0.01 {
        format!("{v:.3}")
    } else {
        format!("{v:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(&["name", "value"]);
        t.row(vec!["alpha".into(), "1".into()]);
        t.row(vec!["b".into(), "12345".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        assert!(r.contains("| alpha | 1     |"));
        assert!(r.contains("| b     | 12345 |"));
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new("x").header(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234.0), "1234");
        assert_eq!(fnum(12.34), "12.3");
        assert_eq!(fnum(0.1234), "0.123");
        assert_eq!(fnum(0.00012), "1.20e-4");
    }
}
