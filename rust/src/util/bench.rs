//! Criterion-style micro/macro bench harness (criterion itself is not in
//! the offline registry). Used by every target in `rust/benches/`.
//!
//! Behaviour: warm up, then run timed iterations until both a minimum
//! iteration count and a minimum wall-clock budget are met; report
//! mean/std/min/p50/p95 and optional throughput. `ADALOMO_BENCH_FAST=1`
//! shrinks budgets so `cargo bench` smoke-runs quickly in CI.
//!
//! Machine-readable side channel: with `ADALOMO_BENCH_JSON=<path>` set,
//! benches record a small set of tracked metrics through a [`JsonSink`]
//! and flush them into one flat JSON object (`make bench-json` writes
//! `BENCH_pipeline.json` this way, and `make bench-check` gates it
//! against `bench/baseline.json` via [`check_against_baseline`]).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::json::{self, Json};
use super::stats::{summarize, Summary};

#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        if fast_mode() {
            BenchConfig {
                warmup_iters: 1,
                min_iters: 3,
                max_iters: 10,
                min_time: Duration::from_millis(50),
            }
        } else {
            BenchConfig {
                warmup_iters: 3,
                min_iters: 10,
                max_iters: 200,
                min_time: Duration::from_millis(500),
            }
        }
    }
}

pub fn fast_mode() -> bool {
    std::env::var("ADALOMO_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

#[derive(Debug)]
pub struct BenchResult {
    pub name: String,
    pub timing: Summary,
    /// Optional work units per iteration (e.g. tokens) for throughput.
    pub units_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn report(&self) {
        let t = &self.timing;
        let mut line = format!(
            "{:44} {:>10}/iter  (± {:>9}, p95 {:>9}, n={})",
            self.name,
            fmt_dur(t.mean),
            fmt_dur(t.std),
            fmt_dur(t.p95),
            t.n
        );
        if let Some(u) = self.units_per_iter {
            line.push_str(&format!("  {:>12.1} units/s", u / t.mean));
        }
        println!("{line}");
    }
}

/// Run `f` under the default config; returns per-iteration seconds summary.
pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench_cfg(name, BenchConfig::default(), None, f)
}

/// Like [`bench`] but reports `units`/second throughput (e.g. tokens).
pub fn bench_units<F: FnMut()>(name: &str, units: f64, f: F) -> BenchResult {
    bench_cfg(name, BenchConfig::default(), Some(units), f)
}

pub fn bench_cfg<F: FnMut()>(
    name: &str,
    cfg: BenchConfig,
    units_per_iter: Option<f64>,
    mut f: F,
) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::new();
    let started = Instant::now();
    while samples.len() < cfg.min_iters
        || (started.elapsed() < cfg.min_time && samples.len() < cfg.max_iters)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let result = BenchResult {
        name: name.to_string(),
        timing: summarize(&samples),
        units_per_iter,
    };
    result.report();
    result
}

fn fmt_dur(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

/// Collector for the tracked bench metrics. Construct with [`from_env`]
/// (`ADALOMO_BENCH_JSON=<path>`; disabled when unset), record with
/// [`metric`], write with [`flush`]. Flushing MERGES into the file's
/// existing JSON object, so the bench processes `make bench-json` runs
/// sequentially can share one output file.
///
/// [`from_env`]: JsonSink::from_env
/// [`metric`]: JsonSink::metric
/// [`flush`]: JsonSink::flush
pub struct JsonSink {
    path: Option<PathBuf>,
    metrics: Vec<(String, f64)>,
}

impl JsonSink {
    pub fn from_env() -> JsonSink {
        Self::at(std::env::var("ADALOMO_BENCH_JSON").ok().map(PathBuf::from))
    }

    /// Explicit-path constructor (`None` disables; used by tests).
    pub fn at(path: Option<PathBuf>) -> JsonSink {
        JsonSink { path, metrics: Vec::new() }
    }

    pub fn enabled(&self) -> bool {
        self.path.is_some()
    }

    /// Record one tracked metric. Recording is unconditional (cheap);
    /// only [`Self::flush`] touches the filesystem.
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.push((name.to_string(), value));
    }

    /// Merge the recorded metrics into the sink file (no-op when
    /// disabled). Later writers win on duplicate names.
    pub fn flush(&self) -> Result<()> {
        let Some(path) = &self.path else { return Ok(()) };
        let mut obj: BTreeMap<String, Json> =
            match std::fs::read_to_string(path) {
                Ok(text) => match Json::parse(&text)
                    .with_context(|| format!("parsing {}", path.display()))?
                {
                    Json::Obj(o) => o,
                    other => bail!(
                        "{} holds {other:?}, not a metrics object",
                        path.display()
                    ),
                },
                Err(_) => BTreeMap::new(),
            };
        for (k, v) in &self.metrics {
            obj.insert(k.clone(), json::num(*v));
        }
        std::fs::write(path, Json::Obj(obj).to_string())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }
}

/// One gated metric's verdict from [`check_against_baseline`].
#[derive(Debug, Clone)]
pub struct GateRow {
    pub name: String,
    pub baseline: f64,
    pub current: f64,
    /// Allowed relative slack, from the baseline file (0.2 = 20%).
    pub tolerance: f64,
    /// `"lower"` (is better), `"higher"` (is better), or `"exact"`
    /// (deterministic: ANY drift beyond tolerance — either way — fails,
    /// so improvements force a re-bless instead of silently de-syncing
    /// the baseline).
    pub direction: String,
    pub failed: bool,
}

/// Compare measured metrics (flat `{name: number}` object) against a
/// baseline (`{name: {value, tolerance, direction}}`). A `lower` metric
/// fails when `current > value * (1 + tolerance)`; a `higher` metric when
/// `current < value * (1 - tolerance)`; an `exact` metric when
/// `|current - value| > |value| * tolerance` (two-sided — the pin for
/// deterministic byte counts). The metric sets must match in BOTH
/// directions: a bench silently dropping a tracked metric is itself a
/// regression, and a newly-recorded metric without a baseline entry
/// (stated tolerance + direction) would be silently ungated forever.
pub fn check_against_baseline(
    current: &Json,
    baseline: &Json,
) -> Result<Vec<GateRow>> {
    let untracked: Vec<&String> = current
        .as_obj()?
        .keys()
        .filter(|k| baseline.opt(k).is_none())
        .collect();
    if !untracked.is_empty() {
        bail!(
            "measured metrics missing from the baseline: {untracked:?} — \
             add entries (value + stated tolerance + direction) to track \
             them"
        );
    }
    let mut rows = Vec::new();
    for (name, spec) in baseline.as_obj()? {
        let value = spec.get("value")?.as_f64()?;
        let tolerance = spec.get("tolerance")?.as_f64()?;
        ensure_direction(spec.get("direction")?.as_str()?)?;
        let direction = spec.get("direction")?.as_str()?.to_string();
        let measured = current
            .get(name)
            .with_context(|| {
                format!("tracked metric {name:?} missing from measurement")
            })?
            .as_f64()?;
        let failed = match direction.as_str() {
            "lower" => measured > value * (1.0 + tolerance),
            "higher" => measured < value * (1.0 - tolerance),
            _ => (measured - value).abs() > value.abs() * tolerance,
        };
        rows.push(GateRow {
            name: name.clone(),
            baseline: value,
            current: measured,
            tolerance,
            direction,
            failed,
        });
    }
    Ok(rows)
}

fn ensure_direction(d: &str) -> Result<()> {
    if d != "lower" && d != "higher" && d != "exact" {
        bail!(
            "direction must be \"lower\", \"higher\" or \"exact\", got {d:?}"
        );
    }
    Ok(())
}

/// Intentional re-baseline: return `baseline` with every metric's `value`
/// replaced by the measurement, keeping each entry's STATED tolerance and
/// direction (which is why blessing, not copying the flat measurement
/// file over the baseline, is the documented override — the structured
/// spec must survive the bump). Fails on metric-set mismatch, same as the
/// gate: a new metric needs a hand-written entry first.
pub fn bless_baseline(current: &Json, baseline: &Json) -> Result<Json> {
    // Validate both files and the metric sets first.
    check_against_baseline(current, baseline)?;
    let mut out = baseline.as_obj()?.clone();
    for (name, spec) in out.iter_mut() {
        let measured = current.get(name)?.as_f64()?;
        let Json::Obj(fields) = spec else {
            bail!("baseline entry {name:?} is not an object");
        };
        fields.insert("value".to_string(), json::num(measured));
    }
    Ok(Json::Obj(out))
}

/// Bench-file banner (each bench target calls this first).
pub fn banner(what: &str, paper_ref: &str) {
    println!("\n=== {what} ===");
    println!("reproduces: {paper_ref}");
    if fast_mode() {
        println!("(ADALOMO_BENCH_FAST=1: reduced iteration budget)");
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_min_iters() {
        let mut count = 0usize;
        let cfg = BenchConfig {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 5,
            min_time: Duration::from_millis(0),
        };
        let r = bench_cfg("t", cfg, None, || count += 1);
        assert_eq!(r.timing.n, 5);
        assert_eq!(count, 7); // 2 warmup + 5 timed
    }

    #[test]
    fn format_durations() {
        assert!(fmt_dur(2.5e-9).ends_with("ns"));
        assert!(fmt_dur(2.5e-6).ends_with("µs"));
        assert!(fmt_dur(2.5e-3).ends_with("ms"));
        assert!(fmt_dur(2.5).ends_with('s'));
    }

    #[test]
    fn json_sink_merges_across_flushes() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "adalomo_sink_test_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        // First bench process.
        let mut a = JsonSink::at(Some(path.clone()));
        assert!(a.enabled());
        a.metric("alpha", 1.5);
        a.metric("beta", 2.0);
        a.flush().unwrap();
        // Second process: adds a metric, overrides one.
        let mut b = JsonSink::at(Some(path.clone()));
        b.metric("beta", 3.0);
        b.metric("gamma", 4.0);
        b.flush().unwrap();
        let merged =
            Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(merged.get("alpha").unwrap().as_f64().unwrap(), 1.5);
        assert_eq!(merged.get("beta").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(merged.get("gamma").unwrap().as_f64().unwrap(), 4.0);
        std::fs::remove_file(&path).unwrap();
        // Disabled sink: records silently, flush is a no-op.
        let mut off = JsonSink::at(None);
        assert!(!off.enabled());
        off.metric("x", 1.0);
        off.flush().unwrap();
    }

    #[test]
    fn gate_passes_within_and_fails_beyond_tolerance() {
        let baseline = Json::parse(
            r#"{
              "step_ns": {"value": 10.0, "tolerance": 0.2, "direction": "lower"},
              "overlap": {"value": 1.5, "tolerance": 0.2, "direction": "higher"}
            }"#,
        )
        .unwrap();
        // Within tolerance on both (improvement on step_ns is fine).
        let ok = Json::parse(r#"{"step_ns": 11.9, "overlap": 1.21}"#).unwrap();
        let rows = check_against_baseline(&ok, &baseline).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| !r.failed), "{rows:?}");
        // lower-direction metric regressing past +20% fails.
        let slow =
            Json::parse(r#"{"step_ns": 12.1, "overlap": 1.6}"#).unwrap();
        let rows = check_against_baseline(&slow, &baseline).unwrap();
        assert!(
            rows.iter().any(|r| r.name == "step_ns" && r.failed),
            "{rows:?}"
        );
        // higher-direction metric collapsing past -20% fails.
        let flat =
            Json::parse(r#"{"step_ns": 9.0, "overlap": 1.19}"#).unwrap();
        let rows = check_against_baseline(&flat, &baseline).unwrap();
        assert!(
            rows.iter().any(|r| r.name == "overlap" && r.failed),
            "{rows:?}"
        );
        // A tracked metric missing from the measurement is an error, as
        // is a malformed direction, as is a measured metric nobody
        // baselined (it would otherwise be ungated forever).
        let partial = Json::parse(r#"{"step_ns": 9.0}"#).unwrap();
        assert!(check_against_baseline(&partial, &baseline).is_err());
        let extra = Json::parse(
            r#"{"step_ns": 9.0, "overlap": 1.5, "novel": 3.0}"#,
        )
        .unwrap();
        let err = check_against_baseline(&extra, &baseline).unwrap_err();
        assert!(format!("{err:#}").contains("novel"));
        let bad_dir = Json::parse(
            r#"{"m": {"value": 1.0, "tolerance": 0.1, "direction": "up"}}"#,
        )
        .unwrap();
        let m = Json::parse(r#"{"m": 1.0}"#).unwrap();
        assert!(check_against_baseline(&m, &bad_dir).is_err());
    }

    #[test]
    fn exact_direction_pins_both_ways() {
        let baseline = Json::parse(
            r#"{"bytes": {"value": 4096, "tolerance": 0.0, "direction": "exact"}}"#,
        )
        .unwrap();
        let same = Json::parse(r#"{"bytes": 4096}"#).unwrap();
        let rows = check_against_baseline(&same, &baseline).unwrap();
        assert!(!rows[0].failed);
        // A regression fails — and so does an IMPROVEMENT: deterministic
        // pins must be re-blessed, never silently de-synced.
        for drifted in [r#"{"bytes": 4100}"#, r#"{"bytes": 2048}"#] {
            let cur = Json::parse(drifted).unwrap();
            let rows = check_against_baseline(&cur, &baseline).unwrap();
            assert!(rows[0].failed, "{drifted}");
        }
    }

    #[test]
    fn bless_updates_values_and_keeps_specs() {
        let baseline = Json::parse(
            r#"{
              "step_ns": {"value": 10.0, "tolerance": 0.2, "direction": "lower"},
              "overlap": {"value": 1.5, "tolerance": 0.2, "direction": "higher"}
            }"#,
        )
        .unwrap();
        // Blessing works even when the gate would fail (that is its job).
        let current =
            Json::parse(r#"{"step_ns": 40.0, "overlap": 1.1}"#).unwrap();
        let blessed = bless_baseline(&current, &baseline).unwrap();
        let step = blessed.get("step_ns").unwrap();
        assert_eq!(step.get("value").unwrap().as_f64().unwrap(), 40.0);
        assert_eq!(step.get("tolerance").unwrap().as_f64().unwrap(), 0.2);
        assert_eq!(
            step.get("direction").unwrap().as_str().unwrap(),
            "lower"
        );
        // The blessed file gates clean against the same measurement.
        let rows = check_against_baseline(&current, &blessed).unwrap();
        assert!(rows.iter().all(|r| !r.failed));
        // Metric-set mismatches still refuse to bless.
        let partial = Json::parse(r#"{"step_ns": 9.0}"#).unwrap();
        assert!(bless_baseline(&partial, &baseline).is_err());
    }
}
