//! Criterion-style micro/macro bench harness (criterion itself is not in
//! the offline registry). Used by every target in `rust/benches/`.
//!
//! Behaviour: warm up, then run timed iterations until both a minimum
//! iteration count and a minimum wall-clock budget are met; report
//! mean/std/min/p50/p95 and optional throughput. `ADALOMO_BENCH_FAST=1`
//! shrinks budgets so `cargo bench` smoke-runs quickly in CI.

use std::time::{Duration, Instant};

use super::stats::{summarize, Summary};

#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        if fast_mode() {
            BenchConfig {
                warmup_iters: 1,
                min_iters: 3,
                max_iters: 10,
                min_time: Duration::from_millis(50),
            }
        } else {
            BenchConfig {
                warmup_iters: 3,
                min_iters: 10,
                max_iters: 200,
                min_time: Duration::from_millis(500),
            }
        }
    }
}

pub fn fast_mode() -> bool {
    std::env::var("ADALOMO_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

#[derive(Debug)]
pub struct BenchResult {
    pub name: String,
    pub timing: Summary,
    /// Optional work units per iteration (e.g. tokens) for throughput.
    pub units_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn report(&self) {
        let t = &self.timing;
        let mut line = format!(
            "{:44} {:>10}/iter  (± {:>9}, p95 {:>9}, n={})",
            self.name,
            fmt_dur(t.mean),
            fmt_dur(t.std),
            fmt_dur(t.p95),
            t.n
        );
        if let Some(u) = self.units_per_iter {
            line.push_str(&format!("  {:>12.1} units/s", u / t.mean));
        }
        println!("{line}");
    }
}

/// Run `f` under the default config; returns per-iteration seconds summary.
pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench_cfg(name, BenchConfig::default(), None, f)
}

/// Like [`bench`] but reports `units`/second throughput (e.g. tokens).
pub fn bench_units<F: FnMut()>(name: &str, units: f64, f: F) -> BenchResult {
    bench_cfg(name, BenchConfig::default(), Some(units), f)
}

pub fn bench_cfg<F: FnMut()>(
    name: &str,
    cfg: BenchConfig,
    units_per_iter: Option<f64>,
    mut f: F,
) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::new();
    let started = Instant::now();
    while samples.len() < cfg.min_iters
        || (started.elapsed() < cfg.min_time && samples.len() < cfg.max_iters)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let result = BenchResult {
        name: name.to_string(),
        timing: summarize(&samples),
        units_per_iter,
    };
    result.report();
    result
}

fn fmt_dur(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

/// Bench-file banner (each bench target calls this first).
pub fn banner(what: &str, paper_ref: &str) {
    println!("\n=== {what} ===");
    println!("reproduces: {paper_ref}");
    if fast_mode() {
        println!("(ADALOMO_BENCH_FAST=1: reduced iteration budget)");
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_min_iters() {
        let mut count = 0usize;
        let cfg = BenchConfig {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 5,
            min_time: Duration::from_millis(0),
        };
        let r = bench_cfg("t", cfg, None, || count += 1);
        assert_eq!(r.timing.n, 5);
        assert_eq!(count, 7); // 2 warmup + 5 timed
    }

    #[test]
    fn format_durations() {
        assert!(fmt_dur(2.5e-9).ends_with("ns"));
        assert!(fmt_dur(2.5e-6).ends_with("µs"));
        assert!(fmt_dur(2.5e-3).ends_with("ms"));
        assert!(fmt_dur(2.5).ends_with('s'));
    }
}
