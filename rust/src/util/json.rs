//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Used for `artifacts/manifest.json` (read) and the JSONL run logs
//! (write). Supports the full JSON grammar except `\u` surrogate pairs
//! beyond the BMP (the manifest is plain ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialization is
/// deterministic — run logs diff cleanly.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(anyhow!("expected number, got {other:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(anyhow!("expected string, got {:.60?}", other)),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(anyhow!("expected array, got {:.60?}", other)),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(anyhow!("expected object, got {:.60?}", other)),
        }
    }

    /// Field access: `j.get("entries")?`.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Optional field access.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building log records.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("truncated \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u{code:04x}"))?,
                            );
                        }
                        c => bail!("bad escape \\{:?}", c as char),
                    }
                }
                // Multi-byte UTF-8: copy raw bytes of this char.
                b if b >= 0x80 => {
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let chunk = self
                        .bytes
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("truncated utf-8"))?;
                    out.push_str(std::str::from_utf8(chunk)?);
                    self.pos = start + len;
                }
                b => out.push(b as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number {text:?} at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":{"e1":{"inputs":[{"dtype":"f32","shape":[4]}]}},"version":1}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode_string() {
        let j = Json::parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "café é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn number_formatting() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
