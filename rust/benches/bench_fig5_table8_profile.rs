//! Regenerates paper Fig. 5 + Table 8 (§4.4): memory footprint and
//! throughput per method & model size — the calibrated analytic models
//! against the paper's published numbers, plus measured local step times.

use adalomo::data::{loader::DataLoader, Domain};
use adalomo::experiments as exp;
use adalomo::memsim::{memory, paper, throughput, Arch};
use adalomo::runtime::Manifest;
use adalomo::util::bench::{banner, bench_units, fast_mode};
use adalomo::util::table::{fnum, Table};

fn main() {
    banner(
        "Fig. 5 / Table 8 — memory & throughput profile",
        "AdaLomo paper §4.4: AdaLomo ~ LOMO ~ LoRA memory; TGS same level, AdaLomo lowest",
    );

    // ---- memory ------------------------------------------------------------
    let act = memory::calibrate();
    let mut tm = Table::new("memory (GB): modeled vs paper")
        .header(&["model", "method", "modeled", "paper", "err"]);
    let mut worst: f64 = 0.0;
    for &(arch_name, method, gpus, mb, paper_gb, _) in paper::TABLE8 {
        let est = memory::estimate(
            &memory::TrainSetup {
                arch: Arch::analytic(arch_name).unwrap(),
                method: memory::Method::parse(method).unwrap(),
                n_gpus: gpus,
                micro_batch: mb,
                seq_len: paper::PROFILE_SEQ_LEN,
            },
            act,
        )
        .total_gb();
        worst = worst.max(((est - paper_gb) / paper_gb).abs());
        tm.row(vec![
            arch_name.into(),
            method.into(),
            fnum(est),
            fnum(paper_gb),
            format!("{:+.0}%", 100.0 * (est - paper_gb) / paper_gb),
        ]);
    }
    tm.print();
    println!("worst memory error: {:.0}%\n", worst * 100.0);

    // ---- throughput ---------------------------------------------------------
    let hw = throughput::Hardware::default();
    let eff = throughput::calibrate();
    println!(
        "calibrated: mxu_eff {:.3}, exposed_comm {:.3}",
        eff.mxu_eff, eff.exposed_comm
    );
    let mut tt = Table::new("throughput (TGS): modeled vs paper")
        .header(&["model", "method", "modeled", "paper", "err"]);
    for &(arch_name, method, gpus, mb, _, paper_tgs) in paper::TABLE8 {
        let tgs = throughput::tgs(
            &memory::TrainSetup {
                arch: Arch::analytic(arch_name).unwrap(),
                method: memory::Method::parse(method).unwrap(),
                n_gpus: gpus,
                micro_batch: mb,
                seq_len: paper::PROFILE_SEQ_LEN,
            },
            hw,
            eff,
        );
        tt.row(vec![
            arch_name.into(),
            method.into(),
            fnum(tgs),
            fnum(paper_tgs),
            format!("{:+.0}%", 100.0 * (tgs - paper_tgs) / paper_tgs),
        ]);
    }
    tt.print();

    // ---- measured: real per-method step cost on this host ------------------
    if exp::artifacts_available() {
        let session = exp::open_session().unwrap();
        let preset = "nano";
        let p = session.manifest.preset(preset).unwrap().clone();
        let (b, t) = (p.batch_size, p.seq_len);
        let tokens = (b * t) as f64;
        let methods: &[&str] = if fast_mode() {
            &["lomo", "adalomo"]
        } else {
            &["sgd", "adamw", "adafactor", "lora", "lomo", "adalomo"]
        };
        println!("\nmeasured end-to-end step (nano, CPU PJRT):");
        for opt in methods {
            let entry = Manifest::train_step_name(preset, opt);
            session.compile(&entry).unwrap();
            let seed = session.upload_i32(&[1], &[]).unwrap();
            let mut blob = session
                .execute_buf(&Manifest::init_name(preset, opt), &[&seed])
                .unwrap();
            let mut loader = DataLoader::lm(Domain::C4, 3, b, t, 200_000);
            let mut step = 0f32;
            bench_units(&format!("train_step_{preset}_{opt}"), tokens, || {
                step += 1.0;
                let batch = loader.next_batch();
                let x = session.upload_i32(&batch.x, &[b, t]).unwrap();
                let y = session.upload_i32(&batch.y, &[b, t]).unwrap();
                let sched = session
                    .upload_f32(&[1e-3, step, 0.0, 1.0], &[4])
                    .unwrap();
                blob = session
                    .execute_buf(&entry, &[&blob, &x, &y, &sched])
                    .unwrap();
            });
        }
    }
}
