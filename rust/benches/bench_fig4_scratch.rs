//! Regenerates paper Fig. 4 (§4.3): from-scratch pre-training on the C4
//! stand-in; SGD vs Adafactor vs AdamW vs AdaLomo.

use adalomo::experiments as exp;
use adalomo::util::bench::{banner, fast_mode};
use adalomo::util::table::{fnum, Table};

fn main() {
    banner(
        "Fig. 4 — from-scratch pre-training",
        "AdaLomo paper Fig. 4: AdamW ≈ Adafactor ≈ AdaLomo ≫ SGD on C4",
    );
    if !exp::artifacts_available() {
        println!("skipped: run `make artifacts` first");
        return;
    }
    let steps = if fast_mode() { 40 } else { 200 };
    let session = exp::open_session().unwrap();
    let opts = ["sgd", "adafactor", "adamw", "adalomo"];
    let reports = exp::optimizer_comparison(
        &session, "nano", &opts, steps, 42, "runs/bench",
    )
    .unwrap();

    let mut t = Table::new(&format!(
        "final metrics after {steps} steps (nano, warmup 3%, cosine)"
    ))
    .header(&["optimizer", "final loss", "val ppl", "val acc"]);
    for opt in opts {
        let r = &reports[opt];
        let (ppl, acc) = r
            .eval_curve
            .last()
            .map(|&(_, p, a)| (p, a))
            .unwrap_or((f64::NAN, f64::NAN));
        t.row(vec![opt.into(), fnum(r.final_loss as f64), fnum(ppl), fnum(acc)]);
    }
    t.print();

    let sgd = reports["sgd"].final_loss;
    let adaptive_max = ["adafactor", "adamw", "adalomo"]
        .iter()
        .map(|o| reports[*o].final_loss)
        .fold(f32::MIN, f32::max);
    println!(
        "adaptive trio clearly beats SGD: {}",
        if adaptive_max < sgd {
            "✓ (Fig. 4 shape reproduced)"
        } else {
            "✗"
        }
    );
    // AdaLomo within a band of AdamW (comparable convergence claim).
    let gap = (reports["adalomo"].final_loss - reports["adamw"].final_loss).abs();
    println!("|loss(AdaLomo) − loss(AdamW)| = {gap:.3} (paper: curves overlap)");
}
