//! Regenerates paper Figs. 7-8 (Appendix B): AdaLomo with vs without
//! gradient normalization on both domains — convergence must be unaffected
//! (grouped update normalization replaces the global norm), while the
//! two-backward-pass cost of the LOMO-style norm shows up in time.

use adalomo::data::Domain;
use adalomo::experiments as exp;
use adalomo::memsim::{liveness, throughput, Arch};
use adalomo::util::bench::{banner, fast_mode};
use adalomo::util::table::{fnum, Table};

fn main() {
    banner(
        "Figs. 7-8 — gradient normalization ablation",
        "AdaLomo paper Appendix B: ±grad-norm curves coincide; grad-norm costs a 2nd backward",
    );
    if !exp::artifacts_available() {
        println!("skipped: run `make artifacts` first");
        return;
    }
    let steps = if fast_mode() { 40 } else { 150 };
    let session = exp::open_session().unwrap();
    let base =
        exp::ensure_base_checkpoint(&session, "nano", 300, 42, "runs/bench")
            .unwrap();

    let mut t = Table::new(&format!("{steps} further-pretraining steps (nano)"))
        .header(&["domain", "variant", "final loss", "final ppl"]);
    let mut pairs = Vec::new();
    for domain in [Domain::Chinese, Domain::PythonCode] {
        let mut finals = Vec::new();
        for opt in ["adalomo", "adalomo_gnorm"] {
            let r = exp::further_pretrain(
                &session, "nano", opt, domain, steps, &base, 42, "runs/bench",
            )
            .unwrap();
            let ppl = r.eval_curve.last().map(|e| e.1).unwrap_or(f64::NAN);
            t.row(vec![
                domain.name().into(),
                opt.into(),
                fnum(r.final_loss as f64),
                fnum(ppl),
            ]);
            finals.push(r.final_loss as f64);
        }
        pairs.push((domain.name(), finals[0], finals[1]));
    }
    t.print();
    for (domain, plain, gnorm) in &pairs {
        let rel = (plain - gnorm).abs() / plain;
        println!(
            "{domain}: |Δloss| = {rel:.2}% — {}",
            if rel < 0.05 {
                "✓ convergence unaffected (paper claim)"
            } else {
                "≈ (increase steps for tighter agreement)"
            }
        );
    }

    // The cost side (paper §2.1: grad-norm LOMO ~doubles training time).
    let arch = Arch::analytic("llama7b").unwrap();
    let two = liveness::simulate(&arch, liveness::BackwardMode::FusedTwoPass);
    println!(
        "\ngrad-norm LOMO needs {} backward passes (modeled slowdown ~{:.1}x, \
         paper: 'almost doubles training time'); grouped normalization: 1 pass.",
        two.backward_passes,
        {
            let hw = throughput::Hardware::default();
            let eff = throughput::calibrate();
            let setup = adalomo::memsim::memory::TrainSetup {
                arch: arch.clone(),
                method: adalomo::memsim::memory::Method::Lomo,
                n_gpus: 4,
                micro_batch: 8,
                seq_len: 2048,
            };
            let one = throughput::step_time(&setup, hw, eff);
            let second_bwd = arch.flops_per_token()
                * (8.0 * 2048.0)
                * (2.0 / 3.0)
                / (hw.peak_flops * eff.mxu_eff);
            (one + second_bwd) / one
        }
    );
}
