//! Regenerates paper Fig. 6 (Appendix A): optimizer trajectories on the
//! two-well landscape, through both the native and the AOT path.

use adalomo::experiments as exp;
use adalomo::optim::OptKind;
use adalomo::util::bench::{banner, bench};
use adalomo::util::table::{fnum, Table};

fn main() {
    banner(
        "Fig. 6 — toy 2-D landscape trajectories",
        "AdaLomo paper, Appendix A: SGD & momentum -> local well; variance & Adam -> global",
    );
    let mut t = Table::new("final basins")
        .header(&["optimizer", "x", "y", "f", "basin", "paper"]);
    let expect = [
        (OptKind::Sgd, "local"),
        (OptKind::SgdMomentum, "local"),
        (OptKind::SgdVariance, "global"),
        (OptKind::AdamW, "global"),
    ];
    for (kind, paper) in expect {
        let traj = exp::toy2d_trajectory(
            kind,
            exp::TOY2D_LR,
            exp::TOY2D_STEPS,
            exp::TOY2D_START,
        );
        let basin = exp::toy2d_basin(&traj);
        let last = traj.last().unwrap();
        t.row(vec![
            kind.name().into(),
            fnum(last.0 as f64),
            fnum(last.1 as f64),
            fnum(last.2 as f64),
            basin.into(),
            paper.into(),
        ]);
        assert!(basin.starts_with(paper), "{kind:?}");
    }
    t.print();
    println!("✓ all four basins match the paper\n");

    bench("toy2d 1000-step trajectory (native, 4 optimizers)", || {
        for kind in [
            OptKind::Sgd,
            OptKind::SgdMomentum,
            OptKind::SgdVariance,
            OptKind::AdamW,
        ] {
            std::hint::black_box(exp::toy2d_trajectory(
                kind, 0.02, 1000, exp::TOY2D_START,
            ));
        }
    });

    if exp::artifacts_available() {
        let session = exp::open_session().unwrap();
        session.compile("toy2d_adamw").unwrap();
        let layout = session.manifest.layout("toy2d/adamw").unwrap().clone();
        let mut blob = vec![0f32; layout.blob_len];
        blob[0] = exp::TOY2D_START.0;
        blob[1] = exp::TOY2D_START.1;
        bench("toy2d 100 steps through PJRT (adamw artifact)", || {
            let mut buf = session
                .upload_f32(&blob, &[layout.blob_len])
                .unwrap();
            for step in 1..=100 {
                let sched = session
                    .upload_f32(&[0.02, step as f32, 0.0, 1.0], &[4])
                    .unwrap();
                buf = session
                    .execute_buf("toy2d_adamw", &[&buf, &sched])
                    .unwrap();
            }
            std::hint::black_box(session.fetch_f32_raw(&buf, 2).unwrap());
        });
    }
}
