//! Micro-benchmarks of the Rust-native optimizer updates (the host-side
//! mirror of the L1 kernels) — the L3 perf-pass baseline for update math.

use adalomo::optim::{OptKind, ParamOpt, ALL_OPTS};
use adalomo::tensor::Tensor;
use adalomo::util::bench::{banner, bench_units};
use adalomo::util::rng::Pcg32;

fn main() {
    banner(
        "micro — native optimizer step cost",
        "supports EXPERIMENTS.md §Perf; shapes of Table-1 memory trade-offs in time",
    );
    let mut rng = Pcg32::seeded(1);
    let shape = [512, 512];
    let elems = (shape[0] * shape[1]) as f64;
    let g = Tensor::from_fn(&shape, |_| rng.normal() * 0.01);

    for kind in ALL_OPTS {
        let mut theta = Tensor::from_fn(&shape, |_| rng.normal() * 0.1);
        let mut opt = ParamOpt::new(kind, &shape);
        let mut t = 0u64;
        bench_units(
            &format!("{} step 512x512", kind.name()),
            elems,
            || {
                t += 1;
                opt.step(&mut theta, &g, t, 1e-3, 0.01);
            },
        );
    }

    // Factored vs full second moment: the memory trade in time terms.
    println!();
    for (label, kind) in [
        ("adalomo (factored v: r,c = m+n floats)", OptKind::AdaLomo),
        ("adamw   (full m,v = 2mn floats)", OptKind::AdamW),
    ] {
        let mut theta = Tensor::from_fn(&shape, |_| rng.normal() * 0.1);
        let mut opt = ParamOpt::new(kind, &shape);
        println!(
            "{label}: state {} floats",
            opt.state_floats()
        );
        let mut t = 0u64;
        bench_units(&format!("{} (state bytes above)", kind.name()), elems, || {
            t += 1;
            opt.step(&mut theta, &g, t, 1e-3, 0.0);
        });
    }
}
