//! Micro-benchmarks of the Rust-native optimizer updates (the host-side
//! mirror of the L1 kernels) — the L3 perf-pass baseline for update math,
//! plus the flat-blob parallel engine versus the per-tensor path.

use adalomo::coordinator::fused_host::{
    fused_host_step, FusedHostGrads, GroupGradSource,
};
use adalomo::coordinator::pipeline;
use adalomo::optim::flat::{seeded_blob_and_grads, synthetic_layout, FlatOptimizer, ShardMode};
use adalomo::optim::{pool, OptKind, ParamOpt, ALL_OPTS};
use adalomo::tensor::Tensor;
use adalomo::util::bench::{banner, bench_units, JsonSink};
use adalomo::util::rng::Pcg32;
use std::sync::RwLock;

/// Model-shaped parameter list (embed + L layers + head) so the engine has
/// a realistic multi-segment workload to shard.
fn model_params(d: usize, ff: usize, v: usize, layers: usize) -> Vec<(String, Vec<usize>)> {
    let mut params = vec![("embed".to_string(), vec![v, d])];
    for l in 0..layers {
        let p = format!("l{l}.");
        params.push((format!("{p}attn_norm"), vec![d]));
        for w in ["wq", "wk", "wv", "wo"] {
            params.push((format!("{p}{w}"), vec![d, d]));
        }
        params.push((format!("{p}ffn_norm"), vec![d]));
        params.push((format!("{p}w_gate"), vec![d, ff]));
        params.push((format!("{p}w_up"), vec![d, ff]));
        params.push((format!("{p}w_down"), vec![ff, d]));
    }
    params.push(("final_norm".to_string(), vec![d]));
    params.push(("head".to_string(), vec![d, v]));
    params
}

fn main() {
    banner(
        "micro — native optimizer step cost",
        "supports EXPERIMENTS.md §Perf; shapes of Table-1 memory trade-offs in time",
    );
    // Tracked-metric sink (ADALOMO_BENCH_JSON; `make bench-json`).
    let mut sink = JsonSink::from_env();
    let mut rng = Pcg32::seeded(1);
    let shape = [512, 512];
    let elems = (shape[0] * shape[1]) as f64;
    let g = Tensor::from_fn(&shape, |_| rng.normal() * 0.01);

    for kind in ALL_OPTS {
        let mut theta = Tensor::from_fn(&shape, |_| rng.normal() * 0.1);
        let mut opt = ParamOpt::new(kind, &shape);
        let mut t = 0u64;
        bench_units(
            &format!("{} step 512x512", kind.name()),
            elems,
            || {
                t += 1;
                opt.step(&mut theta, &g, t, 1e-3, 0.01);
            },
        );
    }

    // Factored vs full second moment: the memory trade in time terms.
    println!();
    for (label, kind) in [
        ("adalomo (factored v: r,c = m+n floats)", OptKind::AdaLomo),
        ("adamw   (full m,v = 2mn floats)", OptKind::AdamW),
    ] {
        let mut theta = Tensor::from_fn(&shape, |_| rng.normal() * 0.1);
        let mut opt = ParamOpt::new(kind, &shape);
        println!(
            "{label}: state {} floats",
            opt.state_floats()
        );
        let mut t = 0u64;
        bench_units(&format!("{} (state bytes above)", kind.name()), elems, || {
            t += 1;
            opt.step(&mut theta, &g, t, 1e-3, 0.0);
        });
    }

    // --- flat-blob engine vs the per-tensor path ---------------------------
    let cores = pool::default_shards();
    println!(
        "\n--- flat-blob engine (model-shaped workload, {} cores) ---",
        cores
    );
    let params = model_params(256, 512, 256, 4);
    let specs: Vec<(&str, &[usize])> =
        params.iter().map(|(n, s)| (n.as_str(), s.as_slice())).collect();

    for kind in [OptKind::AdaLomo, OptKind::AdamW] {
        let layout = synthetic_layout(kind, &specs);
        let (blob0, grads) = seeded_blob_and_grads(&layout, 5);
        let model_elems = layout.params_len as f64;
        println!(
            "{}: {} trainable floats across {} segments",
            kind.name(),
            layout.params_len,
            params.len()
        );

        // Baseline: one ParamOpt + Tensor per parameter (the old path —
        // per-tensor dispatch, fresh u temporary per factored step). The
        // gradient Tensors are hoisted out of the timed closure so both
        // paths time only the update math (the flat engine borrows the
        // gradient image directly).
        let mut tensors: Vec<(Tensor, Tensor, ParamOpt)> = layout
            .trainable()
            .map(|s| {
                let theta = Tensor::new(
                    &s.shape,
                    blob0[s.offset..s.offset + s.size].to_vec(),
                )
                .unwrap();
                let gt = Tensor::new(
                    &s.shape,
                    grads[s.offset..s.offset + s.size].to_vec(),
                )
                .unwrap();
                (theta, gt, ParamOpt::new(kind, &s.shape))
            })
            .collect();
        let mut t = 0u64;
        let per_tensor = bench_units(
            &format!("{} per-tensor ParamOpt step", kind.name()),
            model_elems,
            || {
                t += 1;
                for (theta, gt, opt) in tensors.iter_mut() {
                    opt.step(theta, gt, t, 1e-3, 0.01);
                }
            },
        );

        let mut shard_counts = vec![1usize, 2, cores];
        shard_counts.sort_unstable();
        shard_counts.dedup();
        let mut flat_best: Option<f64> = None;
        for (mode, label) in [
            (ShardMode::Segments, "segments"),
            (ShardMode::Contiguous, "contiguous"),
        ] {
            for &shards in &shard_counts {
                let mut engine =
                    FlatOptimizer::new(kind, &layout, shards, mode).unwrap();
                let mut blob = blob0.clone();
                let mut t = 0u64;
                let r = bench_units(
                    &format!(
                        "{} flat {label} x{shards}",
                        kind.name()
                    ),
                    model_elems,
                    || {
                        t += 1;
                        engine.step(&mut blob, &grads, t, 1e-3, 0.01).unwrap();
                    },
                );
                let mean = r.timing.mean;
                if flat_best.map_or(true, |b| mean < b) {
                    flat_best = Some(mean);
                }
            }

            // Persistent-session path: identical math on the parked
            // crew — the per-step scoped-spawn tax is gone, which is
            // what the re-blessed optim_step baseline banks on.
            let mut engine =
                FlatOptimizer::new(kind, &layout, cores, mode).unwrap();
            let mut blob = blob0.clone();
            let grads_lock = RwLock::new(grads.clone());
            let mut t = 0u64;
            let r = engine
                .session(&mut blob, &grads_lock, |s| {
                    bench_units(
                        &format!(
                            "{} flat session {label} x{cores}",
                            kind.name()
                        ),
                        model_elems,
                        || {
                            t += 1;
                            s.step(t, 1e-3, 0.01).unwrap();
                        },
                    )
                })
                .unwrap();
            let mean = r.timing.mean;
            if flat_best.map_or(true, |b| mean < b) {
                flat_best = Some(mean);
            }
        }
        if let Some(best) = flat_best {
            println!(
                "  => flat engine best {:.2}x vs per-tensor ({:.2}ms vs {:.2}ms)\n",
                per_tensor.timing.mean / best,
                best * 1e3,
                per_tensor.timing.mean * 1e3
            );
            if kind == OptKind::AdaLomo {
                sink.metric(
                    "optim_step_ns_per_elem",
                    best / model_elems * 1e9,
                );
            }
        }
    }

    // --- async rank pipeline: overlap efficiency ---------------------------
    // Exposed step time (modeled critical path: comm serialized on the
    // fabric, optimizer work per bucket starting once its reduction lands)
    // vs the fully-exposed compute + comm sum. On >= 2 ranks the exposed
    // time must sit BELOW the sum — the pipeline's acceptance bar.
    println!("--- async rank pipeline (bucketed exchange overlap) ---");
    let layout = synthetic_layout(OptKind::AdaLomo, &specs);
    let (blob0, _) = seeded_blob_and_grads(&layout, 7);
    let bucket_elems = layout.params_len.div_ceil(16);
    for n_ranks in [2usize, 4, 8] {
        let mut cfg = pipeline::PipelineConfig::new(4, bucket_elems);
        cfg.n_shards = pool::shards_with_reserved(n_ranks).min(4);
        let sources = pipeline::synthetic_sources(n_ranks, 31, 0.02);
        let (_, r) = pipeline::run_pipelined(
            &layout,
            OptKind::AdaLomo,
            ShardMode::Contiguous,
            &blob0,
            sources,
            &cfg,
        )
        .unwrap();
        println!(
            "adalomo pipelined x{} ranks, {} buckets: exposed {:8.3}ms  \
             vs compute+comm {:8.3}ms  (compute {:.3}ms + comm {:.3}ms)  \
             => overlap efficiency {:.2}x",
            r.n_ranks,
            r.n_buckets,
            r.exposed_secs * 1e3,
            (r.compute_secs + r.comm_secs) * 1e3,
            r.compute_secs * 1e3,
            r.comm_secs * 1e3,
            r.overlap_efficiency
        );
        if n_ranks == 4 {
            sink.metric("overlap_efficiency_x4", r.overlap_efficiency);
        }
    }

    // --- fused-backward host mirror: group-granular gradient liveness ------
    // Produce gradients group-by-group (head block, layers L-1..0, embed),
    // stepping each group and freeing its buffer before the next exists:
    // peak live gradient bytes are MEASURED, and the full image is never
    // materialized. The analytic twin is memsim::liveness::simulate_grouped.
    println!("\n--- fused-backward host mirror (group-granular liveness) ---");
    let mut engine = FlatOptimizer::new(
        OptKind::AdaLomo,
        &layout,
        cores.min(4),
        ShardMode::Contiguous,
    )
    .unwrap();
    let mut src = FusedHostGrads::per_rank(&engine, 1, 51, 0.02)
        .pop()
        .unwrap();
    let mut blob = blob0.clone();
    let mut t = 0u64;
    bench_units(
        "adalomo fused-host step (group-by-group)",
        layout.params_len as f64,
        || {
            t += 1;
            fused_host_step(&mut engine, &mut blob, &mut src, t, 1e-3, 0.0)
                .unwrap();
        },
    );
    t += 1;
    let report =
        fused_host_step(&mut engine, &mut blob, &mut src, t, 1e-3, 0.0)
            .unwrap();
    println!(
        "peak live gradient {} bytes over {} groups vs full image {} bytes \
         => {:.1}% live",
        report.peak_live_grad_bytes,
        report.n_groups,
        report.full_grad_bytes,
        100.0 * report.live_fraction()
    );
    sink.metric(
        "fused_host_peak_live_grad_bytes",
        report.peak_live_grad_bytes as f64,
    );
    sink.metric("fused_host_live_fraction", report.live_fraction());

    // Grouped async pipeline: the exchange overlaps group PRODUCTION, and
    // the producing side's window stays far below the full image.
    let n_ranks = 4usize;
    let mut cfg = pipeline::PipelineConfig::new(4, bucket_elems);
    cfg.n_shards = pool::shards_with_reserved(n_ranks).min(4);
    let sources: Vec<Box<dyn GroupGradSource>> =
        FusedHostGrads::per_rank(&engine, n_ranks, 31, 0.02)
            .into_iter()
            .map(|s| Box::new(s) as Box<dyn GroupGradSource>)
            .collect();
    let (_, r) = pipeline::run_pipelined_fused(
        &layout,
        OptKind::AdaLomo,
        ShardMode::Contiguous,
        &blob0,
        sources,
        &cfg,
    )
    .unwrap();
    println!(
        "fused pipelined x{} ranks, {} buckets: exposed {:.3}ms vs \
         compute+comm {:.3}ms ({:.2}x overlap); rank peak live {} of {} \
         grad bytes",
        r.n_ranks,
        r.n_buckets,
        r.exposed_secs * 1e3,
        (r.compute_secs + r.comm_secs) * 1e3,
        r.overlap_efficiency,
        r.peak_live_grad_bytes,
        r.full_grad_bytes
    );
    sink.metric(
        "fused_pipeline_peak_live_grad_bytes",
        r.peak_live_grad_bytes as f64,
    );

    sink.flush().expect("flushing bench metrics");
}
