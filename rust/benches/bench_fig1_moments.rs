//! Regenerates paper Fig. 1 (§2.2): the two-moments ablation on real
//! training — Adam & SGD+variance must reach clearly lower loss than SGD &
//! SGD+momentum. Steps scale down under ADALOMO_BENCH_FAST=1.

use adalomo::experiments as exp;
use adalomo::util::bench::{banner, fast_mode};
use adalomo::util::table::{fnum, Table};

fn main() {
    banner(
        "Fig. 1 — empirical analysis of the two moments",
        "AdaLomo paper Fig. 1: step-like decline for Adam/variance; SGD & momentum lag",
    );
    if !exp::artifacts_available() {
        println!("skipped: run `make artifacts` first");
        return;
    }
    let steps = if fast_mode() { 40 } else { 200 };
    let session = exp::open_session().unwrap();
    let opts = ["sgd", "sgd_momentum", "sgd_variance", "adamw"];
    let reports = exp::optimizer_comparison(
        &session, "nano", &opts, steps, 42, "runs/bench",
    )
    .unwrap();

    let mut t = Table::new(&format!("final loss after {steps} steps (nano)"))
        .header(&["optimizer", "moments", "final loss", "Δ vs sgd"]);
    let sgd_loss = reports["sgd"].final_loss as f64;
    for (opt, moments) in [
        ("sgd", "none"),
        ("sgd_momentum", "first"),
        ("sgd_variance", "second"),
        ("adamw", "both"),
    ] {
        let loss = reports[opt].final_loss as f64;
        t.row(vec![
            opt.into(),
            moments.into(),
            fnum(loss),
            fnum(loss - sgd_loss),
        ]);
    }
    t.print();
    let var = reports["sgd_variance"].final_loss;
    let adam = reports["adamw"].final_loss;
    let mom = reports["sgd_momentum"].final_loss;
    let sgd = reports["sgd"].final_loss;
    println!(
        "second-moment arms beat first-moment arms: {}",
        if var < mom && adam < sgd {
            "✓ (Fig. 1 shape reproduced)"
        } else {
            "✗ (increase steps)"
        }
    );
    for (opt, r) in &reports {
        println!(
            "{opt:14} {:6.1} tokens/s  (loss curve in runs/bench/)",
            r.tokens_per_sec
        );
    }
}
