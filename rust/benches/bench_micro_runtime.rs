//! Micro-benchmarks of the PJRT runtime path: dispatch overhead, host
//! uploads, metrics reads — the L3 hot-path components the perf pass
//! optimizes (EXPERIMENTS.md §Perf).

use adalomo::coordinator::collective::{self, WireCodec};
use adalomo::coordinator::engine::{Engine, ExecPlan, RankSources};
use adalomo::coordinator::pipeline;
use adalomo::data::{loader::DataLoader, Domain};
use adalomo::experiments as exp;
use adalomo::optim::flat::{seeded_blob_and_grads, synthetic_layout, FlatOptimizer, ShardMode};
use adalomo::optim::{pool, OptKind};
use adalomo::runtime::{checkpoint, Manifest};
use adalomo::tensor::Dtype;
use adalomo::util::bench::{banner, bench, bench_units, JsonSink};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// Counting allocator: every heap allocation (and growth-realloc) bumps a
/// counter. The steady-state section snapshots it around a window of
/// persistent-session steps to prove the hot loop is allocation-free —
/// `steady_state_allocs_per_step` is pinned at exactly 0 in
/// bench/baseline.json, so a single stray `Vec` in the step path fails
/// `make bench-gate`.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTING_ALLOC: CountingAlloc = CountingAlloc;

/// Host-side blob operations on the flat engine: the coordinator-path
/// costs that exist even without PJRT (local-SGD round averaging, host
/// mirror steps). Runs before the artifact gate so the bench is useful on
/// a fresh checkout.
fn host_blob_section(sink: &mut JsonSink) {
    let cores = pool::default_shards();
    let params: Vec<(&str, &[usize])> = vec![
        ("embed", &[256, 128]),
        ("l0.wq", &[128, 128]),
        ("l0.w_down", &[256, 128]),
        ("l1.wq", &[128, 128]),
        ("l1.w_down", &[256, 128]),
        ("head", &[128, 256]),
    ];
    let layout = synthetic_layout(OptKind::AdaLomo, &params);
    let (blob0, grads) = seeded_blob_and_grads(&layout, 11);
    println!("host blob: {} floats ({} cores)", layout.blob_len, cores);

    // Local-SGD round averaging over 4 rank blobs (coordinator/workers.rs
    // path, element-parallel on the engine pool).
    let ranks: Vec<Vec<f32>> = (0..4)
        .map(|r| {
            blob0.iter().map(|x| x + r as f32 * 1e-3).collect()
        })
        .collect();
    let sources: Vec<&[f32]> =
        ranks.iter().map(|b| &b[..layout.params_len]).collect();
    let mut avg = vec![0f32; layout.params_len];
    let avg_result = bench_units(
        "round averaging: 4 ranks (par_average)",
        layout.params_len as f64,
        || {
            pool::par_average(&mut avg, &sources, 0.25, cores);
        },
    );
    sink.metric(
        "par_average_ns_per_elem",
        avg_result.timing.mean / layout.params_len as f64 * 1e9,
    );

    // Host-mirror optimizer step on the flat blob.
    let mut engine =
        FlatOptimizer::new(OptKind::AdaLomo, &layout, cores, ShardMode::Contiguous)
            .unwrap();
    let mut blob = blob0.clone();
    let mut t = 0u64;
    let step_result = bench_units(
        "flat adalomo step (contiguous shards)",
        layout.params_len as f64,
        || {
            t += 1;
            engine.step(&mut blob, &grads, t, 1e-3, 0.0).unwrap();
        },
    );
    let step_secs_per_elem =
        step_result.timing.mean / layout.params_len as f64;

    // Persistent-session steady state: the crew is spawned once and
    // parked between rounds, and the first step grows every scratch
    // buffer. After that warm-up, a window of bare steps must perform
    // ZERO heap allocations and ZERO thread spawns — both per-step
    // counters are pinned exactly at 0 in bench/baseline.json. The
    // timing metric is also taken from this path: it is the steady-state
    // cost the coordinator actually pays, minus the per-call spawn tax
    // of the scoped-thread step above.
    let grads_lock = RwLock::new(grads.clone());
    let mut blob = blob0.clone();
    let mut t = 0u64;
    let (sess_mean, d_allocs, d_spawns, window) = engine
        .session(&mut blob, &grads_lock, |s| {
            let r = bench_units(
                "flat adalomo step (persistent session)",
                layout.params_len as f64,
                || {
                    t += 1;
                    s.step(t, 1e-3, 0.0).unwrap();
                },
            );
            // Measured window kept clean of harness allocations:
            // snapshot the counters, run bare steps, diff.
            let window = 64u64;
            let a0 = alloc_count();
            let s0 = pool::spawn_count();
            for _ in 0..window {
                t += 1;
                s.step(t, 1e-3, 0.0).unwrap();
            }
            (
                r.timing.mean,
                alloc_count() - a0,
                pool::spawn_count() - s0,
                window,
            )
        })
        .unwrap();
    sink.metric(
        "host_flat_step_ns_per_elem",
        sess_mean / layout.params_len as f64 * 1e9,
    );
    println!(
        "steady state over {window} session steps: {d_allocs} heap allocs, \
         {d_spawns} thread spawns"
    );
    sink.metric(
        "steady_state_allocs_per_step",
        d_allocs as f64 / window as f64,
    );
    sink.metric(
        "steady_state_thread_spawns_per_step",
        d_spawns as f64 / window as f64,
    );

    // Bucketed-exchange overlap on the same blob (coordinator/pipeline):
    // exposed step time vs the fully-exposed compute + comm sum. The
    // bucket size comes from the fabric model (adaptive sizing): per-
    // bucket fabric cost bounded against the per-bucket step compute just
    // measured above.
    let mut cfg = pipeline::PipelineConfig::adaptive(
        2,
        layout.params_len,
        2,
        Default::default(),
        step_secs_per_elem,
        Dtype::F32,
        None,
    );
    cfg.n_shards = pool::shards_with_reserved(2).min(4);
    println!(
        "adaptive bucket: {} elems for {} total (fabric-latency bound)",
        cfg.bucket_elems, layout.params_len
    );
    let (_, r) = pipeline::run_pipelined(
        &layout,
        OptKind::AdaLomo,
        ShardMode::Contiguous,
        &blob0,
        pipeline::synthetic_sources(2, 3, 0.02),
        &cfg,
    )
    .unwrap();
    println!(
        "pipelined exchange x2 ranks ({} buckets): exposed {:.3}ms vs \
         compute+comm {:.3}ms ({:.2}x overlap)",
        r.n_buckets,
        r.exposed_secs * 1e3,
        (r.compute_secs + r.comm_secs) * 1e3,
        r.overlap_efficiency
    );

    // Engine checkpoint (runtime/checkpoint.rs): the restart-survival
    // path for long pipeline runs — Layout + blob + step counter + plan
    // position, serialized/parsed in full. The file size is tracked
    // exactly (deterministic for a fixed layout + plan encoding): any
    // format change must re-bless the baseline consciously.
    let eng = Engine::new(
        &layout,
        &blob0,
        ExecPlan::pipelined_fused(OptKind::AdaLomo, ShardMode::Contiguous, 2, &cfg),
    )
    .unwrap();
    let ckpt_path = std::env::temp_dir().join(format!(
        "adalomo_bench_ckpt_{}.bin",
        std::process::id()
    ));
    bench_units(
        "engine checkpoint save (layout+blob+plan)",
        layout.blob_len as f64,
        || {
            eng.save(&ckpt_path).unwrap();
        },
    );
    bench_units(
        "engine checkpoint load + validate",
        layout.blob_len as f64,
        || {
            checkpoint::load(&ckpt_path).unwrap();
        },
    );
    let ckpt_bytes = std::fs::metadata(&ckpt_path)
        .expect("checkpoint file written")
        .len();
    println!(
        "checkpoint file: {} bytes for {} blob floats",
        ckpt_bytes, layout.blob_len
    );
    sink.metric("checkpoint_file_bytes", ckpt_bytes as f64);
    std::fs::remove_file(&ckpt_path).ok();

    // --- dtype-aware storage: bf16 blob/comm/checkpoint reductions ----
    // A FIXED bucket size keeps every byte metric an exact integer the
    // baseline pins two-sided (the adaptive sizing above is timing-
    // dependent and would make wire bytes drift run to run).
    let fixed_bucket = layout.params_len.div_ceil(8);
    let mut blob_bytes = [0usize; 2];
    let mut comm_bytes = [0usize; 2];
    for (i, dtype) in [Dtype::F32, Dtype::Bf16].into_iter().enumerate() {
        let mut dcfg = pipeline::PipelineConfig::new(2, fixed_bucket);
        dcfg.n_shards = pool::shards_with_reserved(2).min(4);
        dcfg.dtype = dtype;
        let plan =
            ExecPlan::pipelined(OptKind::AdaLomo, ShardMode::Contiguous, 2, &dcfg);
        let mut eng = Engine::new(&layout, &blob0, plan).unwrap();
        let r = eng
            .run(RankSources::Full(pipeline::synthetic_sources(2, 3, 0.02)))
            .unwrap();
        blob_bytes[i] = r.blob_bytes;
        comm_bytes[i] = r.comm_bytes_per_step;
        let suffix = dtype.name();
        sink.metric(&format!("blob_bytes_{suffix}"), r.blob_bytes as f64);
        sink.metric(
            &format!("peak_comm_bytes_{suffix}"),
            r.peak_comm_bytes as f64,
        );
        sink.metric(
            &format!("overlap_efficiency_{suffix}"),
            r.overlap_efficiency,
        );
        println!(
            "{suffix} storage: blob {} bytes, exchange {} bytes/step \
             (peak tile {}), {:.2}x overlap",
            r.blob_bytes, r.comm_bytes_per_step, r.peak_comm_bytes,
            r.overlap_efficiency
        );
        if dtype == Dtype::Bf16 {
            let p16 = std::env::temp_dir().join(format!(
                "adalomo_bench_ckpt_bf16_{}.bin",
                std::process::id()
            ));
            eng.save(&p16).unwrap();
            let b16 = std::fs::metadata(&p16)
                .expect("bf16 checkpoint written")
                .len();
            println!(
                "bf16 checkpoint file: {} bytes (f32 twin above: {})",
                b16, ckpt_bytes
            );
            sink.metric("checkpoint_file_bytes_bf16", b16 as f64);
            std::fs::remove_file(&p16).ok();
        }
    }
    println!(
        "bf16 vs f32: blob {:.1}%, exchange {:.1}% of the f32 bytes",
        100.0 * blob_bytes[1] as f64 / blob_bytes[0] as f64,
        100.0 * comm_bytes[1] as f64 / comm_bytes[0] as f64
    );

    // --- q8 wire rung: blockwise int8 exchange on f32 storage ---------
    // Same fixed bucket as the dtype cells, so the wire-byte metrics stay
    // exact integers: per 20480-elem tile, 20480 int8 codes + 320 f32
    // block scales = 21760 bytes (26.6% of the f32 tile, under the
    // ladder's <=30% acceptance bar). Metric names are literal — the
    // analyzer's `{suffix}` expansion only covers the storage dtypes.
    {
        let mut qcfg = pipeline::PipelineConfig::new(2, fixed_bucket);
        qcfg.n_shards = pool::shards_with_reserved(2).min(4);
        qcfg.wire = Some(WireCodec::Q8Block);
        let plan =
            ExecPlan::pipelined(OptKind::AdaLomo, ShardMode::Contiguous, 2, &qcfg);
        let mut eng = Engine::new(&layout, &blob0, plan).unwrap();
        let r = eng
            .run(RankSources::Full(pipeline::synthetic_sources(2, 3, 0.02)))
            .unwrap();
        sink.metric("peak_comm_bytes_q8", r.peak_comm_bytes as f64);
        sink.metric("overlap_efficiency_q8", r.overlap_efficiency);
        println!(
            "q8 wire (f32 storage): exchange {} bytes/step (peak tile {}, \
             {:.1}% of f32), {:.2}x overlap",
            r.comm_bytes_per_step,
            r.peak_comm_bytes,
            100.0 * r.comm_bytes_per_step as f64 / comm_bytes[0] as f64,
            r.overlap_efficiency
        );
        // Cheaper wire bytes let the fabric-latency bound afford finer
        // buckets — the overlap-granularity win the codec seam buys.
        let q8_bucket = pipeline::PipelineConfig::adaptive(
            2,
            layout.params_len,
            2,
            Default::default(),
            step_secs_per_elem,
            Dtype::F32,
            Some(WireCodec::Q8Block),
        )
        .bucket_elems;
        println!(
            "adaptive bucket under q8 wire: {} elems vs {} at f32",
            q8_bucket, cfg.bucket_elems
        );
    }

    // --- elastic scale-out: hierarchical fabric + re-plan splice ------
    // hier_allreduce_speedup is the inter-node byte ratio of a flat ring
    // vs the two-level all-reduce at 8 ranks / 4 per node — a pure
    // function of the topology algebra (collective.rs), not a timing, so
    // the baseline pins it EXACT: flat crosses the node boundary from
    // every rank (2 nodes x 2(n-1)/n x B), hierarchical once per node
    // (2(nodes-1)/m x B) = 7.0x fewer inter-node bytes.
    {
        let bytes = 4.0 * layout.params_len as f64;
        let flat = collective::inter_node_bytes_flat(bytes, 8, 4);
        let hier = collective::inter_node_bytes_hier(bytes, 8, 4);
        sink.metric("hier_allreduce_speedup", flat / hier);
        println!(
            "hier allreduce at 8 ranks / 4 per node: {:.0} inter-node \
             bytes flat vs {:.0} hierarchical ({:.1}x)",
            flat,
            hier,
            flat / hier
        );
    }
    // replan_splice_ns: the membership-epoch boundary cost — rebuild the
    // effective plan from its checkpoint record and re-bank the per-rank
    // error-feedback buffers at the incoming fleet size (what
    // Engine::run_elastic does between segments). Timing metric: wide
    // tolerance in the baseline, gated one-sided.
    {
        let mut scfg = pipeline::PipelineConfig::new(4, fixed_bucket);
        scfg.n_shards = 2;
        scfg.wire = Some(WireCodec::Q8Block);
        let mut plan =
            ExecPlan::pipelined(OptKind::AdaLomo, ShardMode::Contiguous, 2, &scfg);
        plan.ranks_schedule = vec![(1, 4), (2, 2), (3, 4)];
        let rec = plan.to_record();
        let splice = bench_units(
            "elastic re-plan splice (from_record + EF re-bank, 4 ranks)",
            layout.params_len as f64,
            || {
                let mut p = ExecPlan::from_record(&rec).unwrap();
                p.n_ranks = p.ranks_for_step(2) as usize;
                p.ranks_schedule.clear();
                let ef = vec![vec![0.0f32; layout.params_len]; p.n_ranks];
                std::hint::black_box((p, ef));
            },
        );
        sink.metric("replan_splice_ns", splice.timing.mean * 1e9);
    }
    // analyze_ns: one full static-analysis pass over this checkout —
    // scan, lex, model build, every rule including the call-graph
    // closure. Gated one-sided with a wide tolerance: this catches the
    // analyzer accidentally going quadratic on the growing tree, not
    // run-to-run noise.
    {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("rust/ sits inside the repo root")
            .to_path_buf();
        let files = adalomo::analysis::run(&root)
            .expect("analyze runs on the checkout")
            .files_scanned as f64;
        let pass = bench_units(
            "static analysis: full-tree pass (per file)",
            files,
            || {
                let report =
                    adalomo::analysis::run(&root).expect("analyze runs");
                std::hint::black_box(report.findings.len());
            },
        );
        sink.metric("analyze_ns", pass.timing.mean * 1e9);
    }
    println!();
}

fn main() {
    banner(
        "micro — runtime dispatch & transfer overhead",
        "hot-path budget: dispatch+upload must be <5% of step time at tiny+",
    );
    let mut sink = JsonSink::from_env();
    host_blob_section(&mut sink);
    sink.flush().expect("flushing bench metrics");
    if !exp::artifacts_available() {
        println!("skipped (PJRT sections): run `make artifacts` first");
        return;
    }
    let session = exp::open_session().unwrap();
    let preset = "nano";
    let p = session.manifest.preset(preset).unwrap().clone();
    let (b, t) = (p.batch_size, p.seq_len);

    // Dispatch floor: the cheapest possible program (8-float slice).
    let entry_metrics = Manifest::read_metrics_name(preset, "adalomo");
    let seed = session.upload_i32(&[1], &[]).unwrap();
    let blob = session
        .execute_buf(&Manifest::init_name(preset, "adalomo"), &[&seed])
        .unwrap();
    session.compile(&entry_metrics).unwrap();
    bench("dispatch floor: read_metrics (slice of 8 floats)", || {
        std::hint::black_box(
            session.execute_buf(&entry_metrics, &[&blob]).unwrap(),
        );
    });
    bench("metrics fetch to host (8 f32)", || {
        let m = session.execute_buf(&entry_metrics, &[&blob]).unwrap();
        std::hint::black_box(session.fetch_f32_raw(&m, 8).unwrap());
    });

    // Host uploads.
    let batch_elems = (b * t) as f64;
    let mut loader = DataLoader::lm(Domain::C4, 5, b, t, 100_000);
    bench_units("batch upload x+y (i32)", 2.0 * batch_elems, || {
        let batch = loader.next_batch();
        std::hint::black_box(session.upload_i32(&batch.x, &[b, t]).unwrap());
        std::hint::black_box(session.upload_i32(&batch.y, &[b, t]).unwrap());
    });
    bench("sched upload (4 f32)", || {
        std::hint::black_box(
            session.upload_f32(&[1e-3, 1.0, 0.0, 1.0], &[4]).unwrap(),
        );
    });

    // The full step for comparison (dispatch share = floor / step).
    let entry = Manifest::train_step_name(preset, "adalomo");
    session.compile(&entry).unwrap();
    let mut blob2 = session
        .execute_buf(&Manifest::init_name(preset, "adalomo"), &[&seed])
        .unwrap();
    let mut step = 0f32;
    bench_units("full train step (nano/adalomo)", batch_elems, || {
        step += 1.0;
        let batch = loader.next_batch();
        let x = session.upload_i32(&batch.x, &[b, t]).unwrap();
        let y = session.upload_i32(&batch.y, &[b, t]).unwrap();
        let sched = session
            .upload_f32(&[1e-3, step, 0.0, 1.0], &[4])
            .unwrap();
        blob2 = session
            .execute_buf(&entry, &[&blob2, &x, &y, &sched])
            .unwrap();
    });

    // Blob checkpoint round-trip (cold path, but should stay sane).
    let layout = session.manifest.layout("nano/adalomo").unwrap();
    bench_units(
        "blob fetch to host (checkpoint path)",
        layout.blob_len as f64,
        || {
            std::hint::black_box(
                session.fetch_f32_raw(&blob2, layout.blob_len).unwrap(),
            );
        },
    );

    let stats = session.stats();
    println!(
        "\nsession totals: {} compiles ({:.2}s), {} executions ({:.2}s), {} uploads ({:.1} MB)",
        stats.compiles,
        stats.compile_secs,
        stats.executions,
        stats.execute_secs,
        stats.host_uploads,
        stats.upload_bytes as f64 / 1e6
    );
}
