//! Regenerates paper Table 1 (model-state memory under mixed precision)
//! and benches the memory-model evaluation itself.

use adalomo::memsim::{memory, Arch};
use adalomo::util::bench::{banner, bench};
use adalomo::util::table::{fnum, Table};

fn main() {
    banner(
        "Table 1 — trainable params & model-state memory",
        "AdaLomo paper, Table 1 (LoRA ~2M / AdamW 16M / AdaLomo ~2M bytes per param)",
    );
    let arch = Arch::analytic("llama7b").unwrap();
    let n = arch.n_params() as f64;
    let mut t = Table::new("regenerated Table 1 (bytes per parameter, M units)")
        .header(&["method", "trainable", "param", "grad", "opt state", "total", "paper"]);
    let rows: [(memory::Method, &str, &str); 3] = [
        (memory::Method::LoRA { rank: 8 }, "N (adapters)", "~2M"),
        (memory::Method::AdamW, "M (all)", "16M"),
        (memory::Method::AdaLomo, "M (all)", "~2M"),
    ];
    for (m, trainable, paper) in rows {
        let b = memory::model_state_bytes(&arch, m);
        t.row(vec![
            m.name().into(),
            trainable.into(),
            fnum(b.params / n),
            fnum(b.gradients / n),
            fnum(b.optimizer_state / n),
            fnum(b.model_state() / n),
            paper.into(),
        ]);
    }
    t.print();

    // Shape assertions (who wins, by what factor).
    let total = |m| memory::model_state_bytes(&arch, m).model_state();
    let ratio = total(memory::Method::AdamW) / total(memory::Method::AdaLomo);
    println!("AdamW / AdaLomo model-state ratio: {ratio:.2} (paper: 16M / ~2M ≈ 8)");
    assert!(ratio > 7.0 && ratio < 8.5);

    // Micro: the closed-form evaluation cost (used inside sweeps).
    bench("memsim::model_state_bytes(llama65b)", || {
        let a = Arch::analytic("llama65b").unwrap();
        for m in memory::PROFILE_METHODS {
            std::hint::black_box(memory::model_state_bytes(&a, m));
        }
    });
    bench("memsim::calibrate (20-row fit)", || {
        std::hint::black_box(memory::calibrate());
    });
}
