//! Regenerates paper Figs. 2-3 (§4.2) and Figs. 9-10 (Appendix D):
//! further pre-training on the chinese / python_code domains; loss,
//! validation perplexity and next-token accuracy per optimizer.

use adalomo::data::Domain;
use adalomo::experiments as exp;
use adalomo::util::bench::{banner, fast_mode};
use adalomo::util::table::{fnum, Table};

fn main() {
    banner(
        "Figs. 2-3 (+9-10) — further pre-training on Chinese / Python code",
        "AdaLomo paper: AdaLomo ≈ AdamW on both domains; Chinese ppl drops far more",
    );
    if !exp::artifacts_available() {
        println!("skipped: run `make artifacts` first");
        return;
    }
    let all = std::env::args().any(|a| a == "--all");
    let steps = if fast_mode() { 40 } else { 160 };
    let session = exp::open_session().unwrap();
    let base = exp::ensure_base_checkpoint(&session, "nano", 300, 42, "runs/bench")
        .unwrap();

    let opts: Vec<&str> = if all {
        vec!["adamw", "adalomo", "adafactor", "sgd"] // Appendix D arms
    } else {
        vec!["adamw", "adalomo"]
    };
    let mut t = Table::new(&format!(
        "further pre-training, {steps} steps from a 300-step base"
    ))
    .header(&["domain", "optimizer", "ppl start", "ppl end", "acc end"]);
    let mut final_ppl = std::collections::BTreeMap::new();
    for domain in [Domain::Chinese, Domain::PythonCode] {
        for opt in &opts {
            let report = exp::further_pretrain(
                &session, "nano", opt, domain, steps, &base, 42, "runs/bench",
            )
            .unwrap();
            let first = report.eval_curve.first().copied().unwrap();
            let last = report.eval_curve.last().copied().unwrap();
            t.row(vec![
                domain.name().into(),
                (*opt).into(),
                fnum(first.1),
                fnum(last.1),
                fnum(last.2),
            ]);
            final_ppl.insert((domain.name(), opt.to_string()), (first.1, last.1));
        }
    }
    t.print();

    // Shape checks.
    let zh_adamw = final_ppl[&("chinese", "adamw".to_string())];
    let py_adamw = final_ppl[&("python_code", "adamw".to_string())];
    println!(
        "\nchinese starts harder than python ({}): {:.1} vs {:.1}",
        if zh_adamw.0 > py_adamw.0 { "✓" } else { "✗" },
        zh_adamw.0,
        py_adamw.0
    );
    let zh_gain = zh_adamw.0 / zh_adamw.1;
    let py_gain = py_adamw.0 / py_adamw.1;
    println!(
        "chinese improves more than python ({}): {zh_gain:.2}x vs {py_gain:.2}x",
        if zh_gain > py_gain { "✓" } else { "✗" }
    );
    let zh_al = final_ppl[&("chinese", "adalomo".to_string())].1;
    println!(
        "AdaLomo ends within 15% of AdamW on chinese ({}): {:.2} vs {:.2}",
        if (zh_al - zh_adamw.1).abs() / zh_adamw.1 < 0.15 { "✓" } else { "≈" },
        zh_al,
        zh_adamw.1
    );
}
