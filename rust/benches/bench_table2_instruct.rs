//! Regenerates paper Table 2 (§4.1) and Table 5 (Appendix C): instruction
//! tuning with {none, LoRA, AdamW, LOMO, AdaLomo} (+ Adafactor with
//! --adafactor), scored on the five-benchmark synthetic suite.

use adalomo::experiments as exp;
use adalomo::memsim::paper::TABLE2_7B_AVG;
use adalomo::util::bench::{banner, fast_mode};
use adalomo::util::table::{fnum, Table};

fn main() {
    banner(
        "Table 2/5 — instruction tuning + five-benchmark suite",
        "AdaLomo paper Table 2: AdaLomo ≈ AdamW > LoRA > LOMO > base (avg)",
    );
    if !exp::artifacts_available() {
        println!("skipped: run `make artifacts` first");
        return;
    }
    let with_adafactor = std::env::args().any(|a| a == "--adafactor");
    let (steps, items) = if fast_mode() { (60, 10) } else { (800, 24) };
    let session = exp::open_session().unwrap();
    let base =
        exp::ensure_base_checkpoint(&session, "nano", 400, 42, "runs/bench")
            .unwrap();

    let mut methods = vec!["none", "lora", "adamw", "lomo", "adalomo"];
    if with_adafactor {
        methods.push("adafactor"); // Table 5 row
    }
    let mut t = Table::new(&format!(
        "nano, {steps} tuning steps, {items} items/benchmark (scores 0-100)"
    ))
    .header(&[
        "method", "knowledge", "reasoning", "arithmetic", "code", "writing",
        "avg", "paper avg (7B)",
    ]);
    let mut avgs = std::collections::BTreeMap::new();
    for method in &methods {
        let outcome = exp::instruction_tune(
            &session, "nano", method, steps, &base, 42, "runs/bench", items,
        )
        .unwrap();
        let paper_avg = TABLE2_7B_AVG
            .iter()
            .find(|(m, _)| m == method)
            .map(|(_, v)| fnum(*v))
            .unwrap_or_else(|| "30.0 (T5)".into());
        t.row(vec![
            (*method).into(),
            fnum(outcome.suite.scores["knowledge"]),
            fnum(outcome.suite.scores["reasoning"]),
            fnum(outcome.suite.scores["arithmetic"]),
            fnum(outcome.suite.scores["code"]),
            fnum(outcome.suite.scores["writing"]),
            fnum(outcome.suite.avg),
            paper_avg,
        ]);
        avgs.insert(method.to_string(), outcome.suite.avg);
    }
    t.print();

    println!("\nshape checks (paper Table 2 orderings):");
    let check = |label: &str, ok: bool| {
        println!("  {} {label}", if ok { "✓" } else { "✗" });
    };
    check("tuned AdaLomo ≥ base model", avgs["adalomo"] >= avgs["none"]);
    check("AdaLomo ≥ LOMO (second moment closes the gap)",
          avgs["adalomo"] >= avgs["lomo"]);
    check("AdamW ≥ base model", avgs["adamw"] >= avgs["none"]);
}
